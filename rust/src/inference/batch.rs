//! Batch-first tiled traversal kernel — the crate's high-throughput
//! execution core.
//!
//! The scalar engines walk one row through the whole forest at a time;
//! each branch node is a dependent load, so the walk stalls on every
//! cache miss. Following Koschel et al. (*Fast Inference of Tree
//! Ensembles on ARM Devices*), this module instead walks **tiles of
//! [`TILE_ROWS`] independent rows in lockstep through each tree**: the
//! per-lane node loads have no data dependence on each other, so the
//! out-of-order core overlaps their miss latency instead of serializing
//! it. On top of that, the whole batch is pre-transformed into
//! ordered-u32 space **once** (FlInt's trick, amortized batch-wide), so
//! the integer variants stay integer-only end to end.
//!
//! ## Three kernels, one dispatch ([`TraversalKernel`])
//!
//! * [`TraversalKernel::Branchy`] — the PR-1 tile walk: each lane tests
//!   for its leaf every step and drops out early. Fewest node visits,
//!   but every step costs two unpredictable branches (`done[r]`, the
//!   leaf test) plus the data-dependent select.
//! * [`TraversalKernel::Branchless`] — the predicated fixed-trip kernel
//!   (FLInt-style). All lanes advance every step via pure arithmetic,
//!   `idx = left + ((x > threshold) & branch_mask)`, leaves absorb via
//!   their self-loops ([`Node8`] encoding), and the loop trip count is
//!   the compiled `tree_depths[t]` — **no data-dependent branches at
//!   all**, a shape LLVM can unroll and autovectorize over the eight
//!   lanes. Lanes that reach a leaf early keep re-loading their parked
//!   node (and row feature 0), which is cheap L1 traffic; what they
//!   never do is mispredict.
//! * [`TraversalKernel::QuickScorer`] — no traversal at all: the forest
//!   is compiled into per-feature threshold-sorted condition streams and
//!   per-tree `u64` false-leaf bitmasks ([`super::quickscorer`]), and a
//!   batch is evaluated by linear scans over those dense arrays with a
//!   cache-blocked trees × row-tiles driver. Trees with more than
//!   [`super::quickscorer::QS_MAX_LEAVES`] leaves fall back per-tree to
//!   the branchless walker (loudly, at plan-build time).
//!
//! The walker kernels are exposed behind one generic monomorphized body
//! (ordered-u32 and f32 domains differ only in the threshold-word
//! compare), shared by all three RF variants *and* the GBT engine; the
//! QuickScorer scan reuses the same crate-internal `Domain` abstraction.
//!
//! ## SIMD backends ([`SimdBackend`], [`super::simd`])
//!
//! Orthogonal to the kernel choice, a runtime-dispatched execution
//! backend selects how the branchless walk and the QuickScorer scan
//! run: portable scalar code, AVX2 intrinsics (8 lane cursors per
//! `__m256i`, `vpgatherdd` node fetches over the compiled SoA mirror
//! planes), or NEON intrinsics (4-lane half-tiles). The branchy kernel
//! is inherently divergent and always runs scalar. Backends are a pure
//! performance knob: every one is bit-identical (the parity suite
//! sweeps kernel × backend).
//!
//! ## Intra-batch threads ([`super::parallel`])
//!
//! A third orthogonal knob: [`accumulate_batch`] splits one batch across
//! a work-stealing pool — tile-aligned row ranges for the walker
//! kernels (each task owns a disjoint accumulator slice), block ×
//! row-range tasks plus an ordered payload fold for QuickScorer (see
//! [`super::parallel`] for the task shapes and the determinism
//! argument). Every worker runs the dispatched kernel × backend on its
//! tasks, and results stay bit-identical at any thread count because no
//! row's accumulation sequence ever changes.
//!
//! ## Parity invariant (load-bearing — the parity suite enforces it)
//!
//! For every engine variant and **every kernel**, the batched results
//! are **bit-identical** to the scalar engines: all kernels route every
//! lane through exactly the same comparisons (the descent predicate is
//! the literal negation `!(x <= t)` of the scalar select — not `x > t`,
//! which would differ under NaN; the predicated step merely masks the
//! compare of a parked lane, and the QuickScorer scan performs the same
//! `x > t` compares against the same threshold words), so each row
//! reaches the same leaf, and leaf payloads are accumulated in ascending
//! tree order — exactly the scalar iteration order — so float sums see
//! the same rounding sequence and u32/i64 sums are exact either way.
//! Kernel choice changes only *when* each tree walk happens, never the
//! per-row accumulation sequence. A ragged final tile (batch %
//! TILE_ROWS rows) runs the *selected* kernel: the branchless walker
//! duplicates the last real lane to fill the tile
//! (`walk_tile_lockstep_tail`, crate-internal) and the QuickScorer scan
//! is per-row anyway, so no kernel silently swaps on the tail.
//!
//! ## Scratch buffers
//!
//! The seed engines transformed rows through a fixed 128-slot stack
//! buffer and rejected wider rows. Both the scalar path
//! ([`with_ordered_row`]) and the batch path now use thread-local
//! growable scratch: no per-call allocation in steady state, no feature
//! count limit (the ≥200-feature regression tests cover this), and no
//! interior-mutability hazard on the `Sync` engines.

use super::compiled::{CompiledForest, Node8};
use super::parallel;
use super::quickscorer::{accumulate_qs, QsBlock, QsPlan};
use super::simd::SimdBackend;
use crate::flint::ordered_u32;
use crate::ir::argmax;
use std::cell::RefCell;

/// Rows walked in lockstep per tile. Eight lanes is enough to cover
/// L2-miss latency with independent work on current cores while the
/// lane state stays in registers / L1 — and eight u32 cursors are one
/// SIMD register wide on AVX2, which is what lets the predicated kernel
/// vectorize.
pub const TILE_ROWS: usize = 8;

/// Which tile-walk strategy the batch entry points use.
///
/// Both produce bit-identical results (module docs); this is purely a
/// performance knob. `Branchless` is the default; the serving
/// coordinator's auto-calibration measures both on the loaded model at
/// startup and keeps the faster one (deep, early-exiting trees can
/// favor `Branchy`, whose visit count tracks the *average* leaf depth
/// rather than the maximum).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TraversalKernel {
    /// Per-lane early exit (the PR-1 tiled kernel).
    Branchy,
    /// Predicated fixed-trip descent over self-looping leaves.
    #[default]
    Branchless,
    /// Bitvector condition-stream evaluation ([`super::quickscorer`]):
    /// no node walks; trees with more than
    /// [`super::quickscorer::QS_MAX_LEAVES`] leaves take the branchless
    /// walker per tree.
    QuickScorer,
}

impl TraversalKernel {
    /// Display / calibration-log name of the kernel.
    pub fn name(self) -> &'static str {
        match self {
            TraversalKernel::Branchy => "branchy",
            TraversalKernel::Branchless => "branchless",
            TraversalKernel::QuickScorer => "quickscorer",
        }
    }

    /// Every kernel (parity suites and the calibrator sweep this).
    pub fn all() -> [TraversalKernel; 3] {
        [TraversalKernel::Branchy, TraversalKernel::Branchless, TraversalKernel::QuickScorer]
    }
}

thread_local! {
    /// Scalar-path scratch: one ordered row.
    static ROW_ORD: RefCell<Vec<u32>> = RefCell::new(Vec::new());
    /// Batch-path scratch: a whole ordered batch.
    static BATCH_ORD: RefCell<Vec<u32>> = RefCell::new(Vec::new());
}

/// Run `f` on `row` transformed into ordered-u32 space using reusable
/// thread-local scratch (replaces the seed's 128-feature stack buffer;
/// any width is supported).
///
/// The buffer is moved out of the slot for the duration of `f`, so a
/// re-entrant call simply allocates a fresh buffer instead of aliasing.
#[inline]
pub fn with_ordered_row<R>(row: &[f32], f: impl FnOnce(&[u32]) -> R) -> R {
    ROW_ORD.with(|cell| {
        let mut buf = cell.take();
        buf.clear();
        buf.extend(row.iter().map(|&x| ordered_u32(x)));
        let out = f(&buf);
        cell.replace(buf);
        out
    })
}

/// Run `f` on a whole row-major batch transformed into ordered-u32 space
/// (one pass, amortized across every tree walk of the batch). Shared
/// with the GBT batch path (`crate::inference::gbt_int`).
#[inline]
pub(crate) fn with_ordered_batch<R>(rows: &[f32], f: impl FnOnce(&[u32]) -> R) -> R {
    BATCH_ORD.with(|cell| {
        let mut buf = cell.take();
        buf.clear();
        buf.extend(rows.iter().map(|&x| ordered_u32(x)));
        let out = f(&buf);
        cell.replace(buf);
        out
    })
}

// ---------------------------------------------------------------------------
// The generic walker: one body, two threshold domains, two kernels.

/// Threshold domain of a walk: how a row element compares against the
/// packed node's 32-bit threshold word. The single generic walker
/// monomorphizes over this, replacing the near-identical
/// `walk_tile_ord`/`walk_tile_f32` pair PR 1 carried.
pub(crate) trait Domain {
    /// Row element type — `Send + Sync` so batches can be shared
    /// read-only across the scheduler's workers.
    type Elem: Copy + Send + Sync;
    /// The negation of the IR's `<=`-goes-left split, i.e. exactly
    /// "take the right child".
    fn go_right(x: Self::Elem, tw: u32) -> bool;
    /// The QuickScorer condition-stream threshold words of this domain
    /// (the plan stores both 32-bit encodings side by side).
    fn qs_words(block: &QsBlock) -> &[u32];

    /// AVX2 predicated fixed-trip tile walk of this domain (see
    /// [`super::simd`]); `row_base[r]` is lane `r`'s row element offset
    /// (clamped-duplicate convention for ragged tails).
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 via [`SimdBackend`] detection and
    /// checked the batch shape ([`walk_tile_predicated`] does both).
    #[cfg(target_arch = "x86_64")]
    unsafe fn walk_tile_avx2(
        trees: &PackedTrees,
        t: usize,
        rows: &[Self::Elem],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    );

    /// NEON predicated fixed-trip tile walk of this domain.
    ///
    /// # Safety
    ///
    /// Caller must have verified NEON via [`SimdBackend`] detection and
    /// checked the batch shape ([`walk_tile_predicated`] does both).
    #[cfg(target_arch = "aarch64")]
    unsafe fn walk_tile_neon(
        trees: &PackedTrees,
        t: usize,
        rows: &[Self::Elem],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    );

    /// AVX2 QuickScorer false-prefix scan: length of the leading
    /// `go_right` run of an ascending condition stream.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 via [`SimdBackend`] detection.
    #[cfg(target_arch = "x86_64")]
    unsafe fn qs_prefix_avx2(x: Self::Elem, words: &[u32]) -> usize;

    /// NEON QuickScorer false-prefix scan.
    ///
    /// # Safety
    ///
    /// Caller must have verified NEON via [`SimdBackend`] detection.
    #[cfg(target_arch = "aarch64")]
    unsafe fn qs_prefix_neon(x: Self::Elem, words: &[u32]) -> usize;
}

/// Ordered-u32 domain (FlInt / InTreeger / GBT walks).
pub(crate) enum OrdDomain {}
impl Domain for OrdDomain {
    type Elem = u32;
    #[inline(always)]
    fn go_right(x: u32, tw: u32) -> bool {
        x > tw
    }
    fn qs_words(block: &QsBlock) -> &[u32] {
        &block.thresh_ord
    }
    #[cfg(target_arch = "x86_64")]
    unsafe fn walk_tile_avx2(
        trees: &PackedTrees,
        t: usize,
        rows: &[u32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        super::simd::avx2::walk_tile_ord(trees, t, rows, row_base, leaves)
    }
    #[cfg(target_arch = "aarch64")]
    unsafe fn walk_tile_neon(
        trees: &PackedTrees,
        t: usize,
        rows: &[u32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        super::simd::neon::walk_tile_ord(trees, t, rows, row_base, leaves)
    }
    #[cfg(target_arch = "x86_64")]
    unsafe fn qs_prefix_avx2(x: u32, words: &[u32]) -> usize {
        super::simd::avx2::qs_false_prefix_ord(x, words)
    }
    #[cfg(target_arch = "aarch64")]
    unsafe fn qs_prefix_neon(x: u32, words: &[u32]) -> usize {
        super::simd::neon::qs_false_prefix_ord(x, words)
    }
}

/// Raw-f32 domain (float baseline walks; `tw` carries the f32 bits).
pub(crate) enum F32Domain {}
impl Domain for F32Domain {
    type Elem = f32;
    #[inline(always)]
    fn go_right(x: f32, tw: u32) -> bool {
        // Written as the literal negation of the IR's `<=`-goes-left
        // split rather than `x > t`: for finite values they are the same
        // predicate (and the same single compare instruction), but under
        // IEEE NaN `x > t` would flip the routing (NaN fails both
        // compares). NaN is rejected at the data boundary, yet keeping
        // the exact negation means even out-of-contract inputs route
        // identically to the seed walkers and the if-else generated C.
        !(x <= f32::from_bits(tw))
    }
    fn qs_words(block: &QsBlock) -> &[u32] {
        &block.thresh_f32
    }
    #[cfg(target_arch = "x86_64")]
    unsafe fn walk_tile_avx2(
        trees: &PackedTrees,
        t: usize,
        rows: &[f32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        super::simd::avx2::walk_tile_f32(trees, t, rows, row_base, leaves)
    }
    #[cfg(target_arch = "aarch64")]
    unsafe fn walk_tile_neon(
        trees: &PackedTrees,
        t: usize,
        rows: &[f32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        super::simd::neon::walk_tile_f32(trees, t, rows, row_base, leaves)
    }
    #[cfg(target_arch = "x86_64")]
    unsafe fn qs_prefix_avx2(x: f32, words: &[u32]) -> usize {
        super::simd::avx2::qs_false_prefix_f32(x, words)
    }
    #[cfg(target_arch = "aarch64")]
    unsafe fn qs_prefix_neon(x: f32, words: &[u32]) -> usize {
        super::simd::neon::qs_false_prefix_f32(x, words)
    }
}

/// A packed forest as the walkers see it — lets the GBT engine reuse the
/// exact same kernels over its own node/offset arrays.
pub(crate) struct PackedTrees<'a> {
    /// All trees' packed nodes, concatenated.
    pub nodes: &'a [Node8],
    /// SIMD gather plane: `nodes[i].tw` as a flat u32 array (same
    /// indexing; see `CompiledForest::soa_tw_ord`).
    pub tw_plane: &'a [u32],
    /// SIMD gather plane: `nodes[i].ff | nodes[i].left << 16`.
    pub ffl_plane: &'a [u32],
    /// Start index of each tree's nodes; length `n_trees + 1`.
    pub tree_offsets: &'a [u32],
    /// Fixed trip count of the branchless kernel; length `n_trees`.
    pub tree_depths: &'a [u32],
    /// Row stride (= feature count) of the row-major batch.
    pub stride: usize,
}

/// Branchy tile walk of one tree: every loop iteration advances all
/// unfinished lanes by one node; lanes drop out at their leaf.
///
/// SAFETY of the unchecked indexing: `Model::validate()` bounds child
/// and feature indices at compile time (packed leaves read feature 0),
/// leaf self-loops stay inside the tree, and the batch drivers assert
/// the row-buffer shape once per call (`(tile_start + tile_rows) *
/// stride <= rows.len()`).
#[inline]
pub(crate) fn walk_tile_branchy<D: Domain>(
    trees: &PackedTrees,
    t: usize,
    rows: &[D::Elem],
    tile_start: usize,
    tile_rows: usize,
    leaves: &mut [u32; TILE_ROWS],
) {
    debug_assert!(tile_rows <= TILE_ROWS);
    debug_assert!((tile_start + tile_rows) * trees.stride <= rows.len());
    let base = trees.tree_offsets[t] as usize;
    let nodes = trees.nodes;
    let stride = trees.stride;
    let mut idx = [0u32; TILE_ROWS]; // tree-local cursors
    let mut done = [false; TILE_ROWS];
    let mut remaining = tile_rows;
    while remaining > 0 {
        for r in 0..tile_rows {
            if done[r] {
                continue;
            }
            let n = unsafe { *nodes.get_unchecked(base + idx[r] as usize) };
            if n.is_leaf() {
                leaves[r] = n.tw;
                done[r] = true;
                remaining -= 1;
            } else {
                let x = unsafe {
                    *rows.get_unchecked((tile_start + r) * stride + n.feature_index())
                };
                idx[r] = n.left as u32 + D::go_right(x, n.tw) as u32;
            }
        }
    }
}

/// Predicated fixed-trip tile walk of one tree over a **full** tile
/// (exactly [`TILE_ROWS`] lanes — ragged tails go to
/// [`walk_tile_lockstep_tail`], which duplicates the last real lane).
///
/// Every lane advances every step with no data-dependent branch: the
/// descent is `idx = left + ((x > tw) & branch_mask)`, leaves self-loop
/// (their mask is 0), and the loop runs the compiled tree depth. The
/// inner loop has a constant trip count over fixed-size arrays, which is
/// the autovectorization-friendly shape the ISSUE's bench sweep checks.
///
/// SAFETY: same argument as [`walk_tile_branchy`]; additionally the
/// drivers guarantee `tile_start + TILE_ROWS <= n_rows`.
#[inline]
pub(crate) fn walk_tile_lockstep<D: Domain>(
    trees: &PackedTrees,
    t: usize,
    rows: &[D::Elem],
    tile_start: usize,
    leaves: &mut [u32; TILE_ROWS],
) {
    debug_assert!((tile_start + TILE_ROWS) * trees.stride <= rows.len());
    let base = trees.tree_offsets[t] as usize;
    let depth = trees.tree_depths[t];
    let nodes = trees.nodes;
    let stride = trees.stride;
    let mut idx = [0u32; TILE_ROWS]; // tree-local cursors
    for _ in 0..depth {
        for r in 0..TILE_ROWS {
            let n = unsafe { *nodes.get_unchecked(base + idx[r] as usize) };
            let x =
                unsafe { *rows.get_unchecked((tile_start + r) * stride + n.feature_index()) };
            idx[r] = n.left as u32 + (D::go_right(x, n.tw) as u32 & n.branch_mask());
        }
    }
    // After `depth` predicated steps every lane is parked on its leaf
    // (a lane reaching depth d <= depth self-loops for the remainder).
    for r in 0..TILE_ROWS {
        let n = unsafe { *nodes.get_unchecked(base + idx[r] as usize) };
        debug_assert!(n.is_leaf(), "lane not at a leaf after the fixed trip");
        leaves[r] = n.tw;
    }
}

/// Ragged-tail variant of [`walk_tile_lockstep`]: a tile with fewer than
/// [`TILE_ROWS`] rows fills the missing lanes by **duplicating the last
/// real row**, so the whole batch runs the selected predicated kernel
/// (the duplicate lanes' results are discarded). Each real lane performs
/// exactly the comparisons of the full-tile walk, so results stay
/// bit-identical; the duplicates are pure redundant arithmetic.
///
/// SAFETY: same argument as [`walk_tile_lockstep`] — every lane's row
/// index is clamped into `tile_start..tile_start + tile_rows`, which the
/// drivers keep inside the row buffer.
#[inline]
pub(crate) fn walk_tile_lockstep_tail<D: Domain>(
    trees: &PackedTrees,
    t: usize,
    rows: &[D::Elem],
    tile_start: usize,
    tile_rows: usize,
    leaves: &mut [u32; TILE_ROWS],
) {
    debug_assert!(tile_rows >= 1 && tile_rows <= TILE_ROWS);
    debug_assert!((tile_start + tile_rows) * trees.stride <= rows.len());
    let base = trees.tree_offsets[t] as usize;
    let depth = trees.tree_depths[t];
    let nodes = trees.nodes;
    let stride = trees.stride;
    let mut row_base = [0usize; TILE_ROWS];
    for (r, slot) in row_base.iter_mut().enumerate() {
        *slot = (tile_start + r.min(tile_rows - 1)) * stride;
    }
    let mut idx = [0u32; TILE_ROWS]; // tree-local cursors
    for _ in 0..depth {
        for r in 0..TILE_ROWS {
            let n = unsafe { *nodes.get_unchecked(base + idx[r] as usize) };
            let x = unsafe { *rows.get_unchecked(row_base[r] + n.feature_index()) };
            idx[r] = n.left as u32 + (D::go_right(x, n.tw) as u32 & n.branch_mask());
        }
    }
    for r in 0..tile_rows {
        let n = unsafe { *nodes.get_unchecked(base + idx[r] as usize) };
        debug_assert!(n.is_leaf(), "lane not at a leaf after the fixed trip");
        leaves[r] = n.tw;
    }
}

/// Per-lane row element offsets of one tile, with missing lanes clamped
/// to the last real row (the duplicated-lane tail convention of
/// [`walk_tile_lockstep_tail`], shared by the SIMD walkers so full tiles
/// and ragged tails run one intrinsic body).
#[inline]
pub(crate) fn row_base_lanes(
    stride: usize,
    tile_start: usize,
    tile_rows: usize,
) -> [u32; TILE_ROWS] {
    debug_assert!(tile_rows >= 1 && tile_rows <= TILE_ROWS);
    let mut rb = [0u32; TILE_ROWS];
    for (r, slot) in rb.iter_mut().enumerate() {
        *slot = ((tile_start + r.min(tile_rows - 1)) * stride) as u32;
    }
    rb
}

/// Predicated (branchless) tile walk behind the backend dispatch: the
/// scalar lockstep walkers, or the AVX2 / NEON intrinsic walkers of
/// [`super::simd`]. Bit-identical either way — the intrinsic bodies run
/// the exact same compare/mask/add step per lane per level.
///
/// `row_base` is the tile's per-lane row offsets from [`row_base_lanes`]
/// (hoisted to once per tile by the drivers — it is tree-independent,
/// and this dispatch runs once per tree). The non-scalar arms are
/// unreachable unless the matching CPU feature was detected: engines
/// assert availability in `set_backend`, [`accumulate_batch`] — the one
/// funnel into the drivers — re-asserts it per batch (a plain assert:
/// executing an AVX2 block on a non-AVX2 core is undefined behavior,
/// not a panic), and this dispatch keeps a debug tripwire.
#[allow(clippy::too_many_arguments)] // internal hot-path dispatch, mirrors the walker signatures
#[inline]
pub(crate) fn walk_tile_predicated<D: Domain>(
    trees: &PackedTrees,
    t: usize,
    rows: &[D::Elem],
    tile_start: usize,
    tile_rows: usize,
    row_base: &[u32; TILE_ROWS],
    backend: SimdBackend,
    leaves: &mut [u32; TILE_ROWS],
) {
    debug_assert_eq!(*row_base, row_base_lanes(trees.stride, tile_start, tile_rows));
    match backend {
        SimdBackend::Scalar => {
            if tile_rows == TILE_ROWS {
                walk_tile_lockstep::<D>(trees, t, rows, tile_start, leaves)
            } else {
                walk_tile_lockstep_tail::<D>(trees, t, rows, tile_start, tile_rows, leaves)
            }
        }
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 => {
            debug_assert!(SimdBackend::Avx2.is_available());
            // SAFETY: AVX2 availability was asserted by
            // `accumulate_batch`'s per-batch funnel check (and by
            // `set_backend`); the drivers checked the batch shape
            // (`n_rows * stride <= rows.len()`, `rows.len() <=
            // i32::MAX`), `row_base_lanes` clamps every lane into the
            // batch, and `Model::validate()` bounds the node/feature
            // indices the gathers dereference.
            unsafe { D::walk_tile_avx2(trees, t, rows, row_base, leaves) }
        }
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => {
            debug_assert!(SimdBackend::Neon.is_available());
            // SAFETY: NEON availability asserted by the same funnel;
            // same shape and index bounds argument as the AVX2 arm.
            unsafe { D::walk_tile_neon(trees, t, rows, row_base, leaves) }
        }
        other => unreachable!(
            "backend {} cannot execute on this architecture (engines assert availability)",
            other.name()
        ),
    }
}

/// Shared batch driver: walk every (tile, tree) pair with the selected
/// kernel and accumulate leaf payload rows into `acc` (row-major
/// `n_rows * n_classes`, pre-initialized by the caller). Per row,
/// accumulation happens in ascending tree order — the scalar order.
///
/// `qs` carries the compiled QuickScorer plan; it is only consulted when
/// `kernel` is [`TraversalKernel::QuickScorer`] (every engine compiles
/// one, so internal callers always pass `Some`). `backend` selects the
/// SIMD execution of the branchless walk and the QuickScorer scan; the
/// branchy kernel is inherently divergent (per-lane early exit) and
/// always runs scalar. `threads > 1` runs the batch on the
/// work-stealing pool ([`super::parallel`]): tile-aligned row-range
/// tasks, each owning a disjoint slice of `acc`, so every row's
/// accumulation sequence — and therefore every output bit — is
/// unchanged from the single-thread walk.
#[allow(clippy::too_many_arguments)] // internal monomorphized driver; a param struct would obscure the hot path
pub(crate) fn accumulate_batch<D: Domain, T>(
    trees: &PackedTrees,
    qs: Option<&QsPlan>,
    rows: &[D::Elem],
    n_rows: usize,
    n_classes: usize,
    leaf_table: &[T],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
    acc: &mut [T],
) where
    T: Copy + std::ops::AddAssign<T> + Send + Sync,
{
    assert_eq!(acc.len(), n_rows * n_classes);
    assert!(n_rows * trees.stride <= rows.len());
    if backend != SimdBackend::Scalar {
        // Non-scalar callers normally arrive via `set_backend` (which
        // asserts availability); the public `*_exec` entry points can
        // pass a backend directly, so the funnel re-checks — an
        // undetected backend must never reach an intrinsic block.
        assert!(
            backend.is_available(),
            "backend {} selected but not detected on this host",
            backend.name()
        );
        // The AVX2 row gathers index with i32 element offsets; bound the
        // batch once here rather than per gather.
        assert!(rows.len() <= i32::MAX as usize, "batch too large for 32-bit SIMD gathers");
    }
    if kernel == TraversalKernel::QuickScorer {
        let plan = qs.expect("QuickScorer kernel requires a compiled QsPlan");
        accumulate_qs::<D, T>(
            plan, trees, rows, n_rows, n_classes, leaf_table, backend, threads, acc,
        );
        return;
    }
    let n_trees = trees.tree_offsets.len() - 1;
    // One task body shared by the sequential and parallel paths: walk
    // rows `[lo, hi)` through every tree in ascending order,
    // accumulating into `chunk_acc` (that range's slice of `acc`). The
    // row split never touches a row's per-tree accumulation sequence,
    // which is what float rounding and the parity invariant depend on.
    let walk_range = |lo: usize, hi: usize, chunk_acc: &mut [T]| {
        let mut leaves = [0u32; TILE_ROWS];
        let mut tile_start = lo;
        while tile_start < hi {
            let tile_rows = TILE_ROWS.min(hi - tile_start);
            // Tree-independent; computed once per tile, not once per tree.
            let row_base = row_base_lanes(trees.stride, tile_start, tile_rows);
            for t in 0..n_trees {
                if kernel == TraversalKernel::Branchy {
                    walk_tile_branchy::<D>(trees, t, rows, tile_start, tile_rows, &mut leaves);
                } else {
                    // Branchless: backend-dispatched predicated walk (the
                    // ragged tail stays on the selected backend via the
                    // duplicated-lane convention; see the walkers).
                    walk_tile_predicated::<D>(
                        trees, t, rows, tile_start, tile_rows, &row_base, backend, &mut leaves,
                    );
                }
                for (r, &p) in leaves[..tile_rows].iter().enumerate() {
                    let leaf = &leaf_table[p as usize * n_classes..(p as usize + 1) * n_classes];
                    let row_acc = &mut chunk_acc[(tile_start + r - lo) * n_classes
                        ..(tile_start + r - lo + 1) * n_classes];
                    for (a, &v) in row_acc.iter_mut().zip(leaf) {
                        *a += v;
                    }
                }
            }
            tile_start += tile_rows;
        }
    };
    if threads <= 1 {
        walk_range(0, n_rows, acc);
        return;
    }
    // Row-range tasks over the work-stealing pool. Chunk boundaries are
    // tile-aligned ([`parallel::tile_chunks`]), so the duplicated-lane
    // ragged tail fires only on the true final tile of the batch —
    // exactly where the sequential walk runs it.
    let chunks = parallel::tile_chunks(n_rows, TILE_ROWS, threads);
    let slab = parallel::SharedSlab::new(acc);
    parallel::run_tasks(threads, chunks.len(), |i| {
        let (lo, hi) = chunks[i];
        // SAFETY: the chunks partition `0..n_rows` into disjoint row
        // ranges, so no two tasks' accumulator slices overlap.
        let chunk_acc = unsafe { slab.slice_mut(lo * n_classes, (hi - lo) * n_classes) };
        walk_range(lo, hi, chunk_acc);
    });
}

// ---------------------------------------------------------------------------
// Public batch entry points (per variant, with and without kernel choice).

/// Shape-check a flat row-major batch; returns the row count.
fn batch_rows(f: &CompiledForest, rows_len: usize) -> usize {
    assert!(f.n_features > 0);
    assert!(
        rows_len % f.n_features == 0,
        "batch length {} is not a multiple of n_features {}",
        rows_len,
        f.n_features
    );
    rows_len / f.n_features
}

impl CompiledForest {
    /// The packed forest view over the ordered-u32 node array.
    pub(crate) fn packed_ord(&self) -> PackedTrees<'_> {
        PackedTrees {
            nodes: &self.nodes_ord,
            tw_plane: &self.soa_tw_ord,
            ffl_plane: &self.soa_ffl,
            tree_offsets: &self.tree_offsets,
            tree_depths: &self.tree_depths,
            stride: self.n_features,
        }
    }

    /// The packed forest view over the f32-bits node array.
    pub(crate) fn packed_f32(&self) -> PackedTrees<'_> {
        PackedTrees {
            nodes: &self.nodes_f32,
            tw_plane: &self.soa_tw_f32,
            ffl_plane: &self.soa_ffl,
            tree_offsets: &self.tree_offsets,
            tree_depths: &self.tree_depths,
            stride: self.n_features,
        }
    }
}

/// Batched float engine accumulation: averaged per-class probabilities,
/// flat `n_rows * n_classes`, bit-identical to
/// `FloatEngine::accumulate` per row (default kernel).
pub fn float_proba_batch(f: &CompiledForest, rows: &[f32]) -> Vec<f32> {
    float_proba_batch_with(f, rows, TraversalKernel::default())
}

/// [`float_proba_batch`] with an explicit kernel (backend and thread
/// count resolved from the environment / host detection).
pub fn float_proba_batch_with(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
) -> Vec<f32> {
    float_proba_batch_exec(f, rows, kernel, SimdBackend::resolve(), parallel::resolve())
}

/// [`float_proba_batch`] with an explicit kernel, SIMD backend, and
/// intra-batch thread count (results are bit-identical at any count).
pub fn float_proba_batch_exec(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; batch_rows(f, rows.len()) * f.n_classes];
    float_proba_batch_into(f, rows, kernel, backend, threads, &mut out);
    out
}

/// [`float_proba_batch_exec`] writing into a caller-provided flat
/// `n_rows * n_classes` buffer — the allocation-free form the serving
/// hot path reuses across batches. `out` is fully overwritten.
pub fn float_proba_batch_into(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
    out: &mut [f32],
) {
    let n_rows = batch_rows(f, rows.len());
    let c = f.n_classes;
    assert_eq!(out.len(), n_rows * c, "output buffer must be n_rows * n_classes");
    out.fill(0.0);
    accumulate_batch::<F32Domain, f32>(
        &f.packed_f32(),
        Some(&f.qs),
        rows,
        n_rows,
        c,
        &f.leaf_f32,
        kernel,
        backend,
        threads,
        out,
    );
    let inv = 1.0 / f.n_trees as f32;
    for a in out {
        *a *= inv;
    }
}

/// Batched FlInt accumulation: ordered-u32 compares (whole batch
/// transformed once), float accumulation — flat `n_rows * n_classes`,
/// bit-identical to `FlIntEngine`'s per-row path (default kernel).
pub fn flint_proba_batch(f: &CompiledForest, rows: &[f32]) -> Vec<f32> {
    flint_proba_batch_with(f, rows, TraversalKernel::default())
}

/// [`flint_proba_batch`] with an explicit kernel (backend and thread
/// count resolved from the environment / host detection).
pub fn flint_proba_batch_with(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
) -> Vec<f32> {
    flint_proba_batch_exec(f, rows, kernel, SimdBackend::resolve(), parallel::resolve())
}

/// [`flint_proba_batch`] with an explicit kernel, SIMD backend, and
/// intra-batch thread count (results are bit-identical at any count).
pub fn flint_proba_batch_exec(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; batch_rows(f, rows.len()) * f.n_classes];
    flint_proba_batch_into(f, rows, kernel, backend, threads, &mut out);
    out
}

/// [`flint_proba_batch_exec`] writing into a caller-provided flat
/// `n_rows * n_classes` buffer — the allocation-free form the serving
/// hot path reuses across batches. `out` is fully overwritten.
pub fn flint_proba_batch_into(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
    out: &mut [f32],
) {
    let n_rows = batch_rows(f, rows.len());
    let c = f.n_classes;
    assert_eq!(out.len(), n_rows * c, "output buffer must be n_rows * n_classes");
    out.fill(0.0);
    with_ordered_batch(rows, |rows_ord| {
        accumulate_batch::<OrdDomain, f32>(
            &f.packed_ord(),
            Some(&f.qs),
            rows_ord,
            n_rows,
            c,
            &f.leaf_f32,
            kernel,
            backend,
            threads,
            out,
        );
        let inv = 1.0 / f.n_trees as f32;
        for a in out.iter_mut() {
            *a *= inv;
        }
    })
}

/// Batched InTreeger accumulation: ordered-u32 compares, `u32`
/// fixed-point sums — flat `n_rows * n_classes`, bit-identical to
/// `IntEngine::predict_fixed` per row (default kernel). Integer-only
/// after the one batch-wide transform. The u32 adds cannot wrap:
/// `quant::max_accumulated` bounds the sum below `u32::MAX` (same
/// argument as the scalar engine).
pub fn int_fixed_batch(f: &CompiledForest, rows: &[f32]) -> Vec<u32> {
    int_fixed_batch_with(f, rows, TraversalKernel::default())
}

/// [`int_fixed_batch`] with an explicit kernel (backend and thread
/// count resolved from the environment / host detection).
pub fn int_fixed_batch_with(f: &CompiledForest, rows: &[f32], kernel: TraversalKernel) -> Vec<u32> {
    int_fixed_batch_exec(f, rows, kernel, SimdBackend::resolve(), parallel::resolve())
}

/// [`int_fixed_batch`] with an explicit kernel, SIMD backend, and
/// intra-batch thread count (results are bit-identical at any count).
pub fn int_fixed_batch_exec(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
) -> Vec<u32> {
    let mut out = vec![0u32; batch_rows(f, rows.len()) * f.n_classes];
    int_fixed_batch_into(f, rows, kernel, backend, threads, &mut out);
    out
}

/// [`int_fixed_batch_exec`] writing into a caller-provided flat
/// `n_rows * n_classes` buffer — the allocation-free form the serving
/// hot path reuses across batches. `out` is fully overwritten.
pub fn int_fixed_batch_into(
    f: &CompiledForest,
    rows: &[f32],
    kernel: TraversalKernel,
    backend: SimdBackend,
    threads: usize,
    out: &mut [u32],
) {
    let n_rows = batch_rows(f, rows.len());
    let c = f.n_classes;
    assert_eq!(out.len(), n_rows * c, "output buffer must be n_rows * n_classes");
    out.fill(0);
    with_ordered_batch(rows, |rows_ord| {
        accumulate_batch::<OrdDomain, u32>(
            &f.packed_ord(),
            Some(&f.qs),
            rows_ord,
            n_rows,
            c,
            &f.leaf_u32,
            kernel,
            backend,
            threads,
            out,
        );
    })
}

/// Per-row argmax over a flat `n_rows * n_classes` score matrix.
pub fn argmax_rows<T: PartialOrd + Copy>(flat: &[T], n_classes: usize) -> Vec<u32> {
    assert!(n_classes > 0);
    assert!(flat.len() % n_classes == 0);
    flat.chunks_exact(n_classes).map(argmax).collect()
}

/// Split a flat `n_rows * n_classes` matrix into per-row vectors (the
/// shape the serving layer hands back to clients).
pub fn split_rows<T: Clone>(flat: Vec<T>, n_classes: usize) -> Vec<Vec<T>> {
    assert!(n_classes > 0);
    assert!(flat.len() % n_classes == 0);
    flat.chunks_exact(n_classes).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn forest() -> CompiledForest {
        let ds = shuttle_like(1200, 21);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 9, max_depth: 6, ..Default::default() },
            21,
        );
        CompiledForest::compile(&m)
    }

    #[test]
    fn both_kernels_match_scalar_walks() {
        let f = forest();
        let ds = shuttle_like(300, 22);
        let n = 104usize; // 13 full tiles
        let rows = &ds.features[..n * ds.n_features];
        let rows_ord: Vec<u32> = rows.iter().map(|&x| ordered_u32(x)).collect();
        let trees_ord = f.packed_ord();
        let trees_f32 = f.packed_f32();
        let mut leaves_branchy = [0u32; TILE_ROWS];
        let mut leaves_lockstep = [0u32; TILE_ROWS];
        let mut leaves_f32 = [0u32; TILE_ROWS];
        let mut tile_start = 0;
        while tile_start < n {
            for t in 0..f.n_trees {
                walk_tile_branchy::<OrdDomain>(
                    &trees_ord, t, &rows_ord, tile_start, TILE_ROWS, &mut leaves_branchy,
                );
                walk_tile_lockstep::<OrdDomain>(
                    &trees_ord, t, &rows_ord, tile_start, &mut leaves_lockstep,
                );
                walk_tile_lockstep::<F32Domain>(
                    &trees_f32, t, rows, tile_start, &mut leaves_f32,
                );
                for r in 0..TILE_ROWS {
                    let row_ord: Vec<u32> =
                        ds.row(tile_start + r).iter().map(|&x| ordered_u32(x)).collect();
                    let want = f.walk_ord(t, &row_ord);
                    assert_eq!(leaves_branchy[r], want, "branchy t{t} row {}", tile_start + r);
                    assert_eq!(leaves_lockstep[r], want, "lockstep t{t} row {}", tile_start + r);
                    assert_eq!(leaves_f32[r], want, "lockstep-f32 t{t} row {}", tile_start + r);
                }
            }
            tile_start += TILE_ROWS;
        }
    }

    #[test]
    fn batch_shapes_and_kernel_parity() {
        let f = forest();
        let ds = shuttle_like(50, 23);
        let rows = &ds.features[..10 * ds.n_features];
        assert_eq!(float_proba_batch(&f, rows).len(), 10 * f.n_classes);
        assert_eq!(flint_proba_batch(&f, rows).len(), 10 * f.n_classes);
        assert_eq!(int_fixed_batch(&f, rows).len(), 10 * f.n_classes);
        assert!(float_proba_batch(&f, &[]).is_empty());
        for kernel in TraversalKernel::all() {
            assert_eq!(float_proba_batch(&f, rows), float_proba_batch_with(&f, rows, kernel));
            assert_eq!(flint_proba_batch(&f, rows), flint_proba_batch_with(&f, rows, kernel));
            assert_eq!(int_fixed_batch(&f, rows), int_fixed_batch_with(&f, rows, kernel));
            for &backend in SimdBackend::available() {
                for threads in [1usize, 3] {
                    assert_eq!(
                        float_proba_batch(&f, rows),
                        float_proba_batch_exec(&f, rows, kernel, backend, threads),
                        "{}/{}/{}t",
                        kernel.name(),
                        backend.name(),
                        threads
                    );
                    assert_eq!(
                        int_fixed_batch(&f, rows),
                        int_fixed_batch_exec(&f, rows, kernel, backend, threads),
                        "{}/{}/{}t",
                        kernel.name(),
                        backend.name(),
                        threads
                    );
                }
            }
        }
    }

    /// The SIMD predicated walker must agree with the scalar lockstep
    /// walker lane for lane, at every tail width, in both threshold
    /// domains (exercised directly here; the engine-level parity suite
    /// covers the same thing end to end). Runs the intrinsic path only
    /// where the CPU feature was detected.
    #[test]
    fn simd_walkers_match_scalar_lane_for_lane() {
        let f = forest();
        let ds = shuttle_like(64, 25);
        let rows_ord: Vec<u32> = ds.features.iter().map(|&x| ordered_u32(x)).collect();
        let trees_ord = f.packed_ord();
        let trees_f32 = f.packed_f32();
        let mut want = [0u32; TILE_ROWS];
        let mut got = [0u32; TILE_ROWS];
        for &backend in SimdBackend::available() {
            for tile_rows in 1..=TILE_ROWS {
                let rb = row_base_lanes(trees_ord.stride, 0, tile_rows);
                for t in 0..f.n_trees {
                    walk_tile_branchy::<OrdDomain>(
                        &trees_ord, t, &rows_ord, 0, tile_rows, &mut want,
                    );
                    walk_tile_predicated::<OrdDomain>(
                        &trees_ord, t, &rows_ord, 0, tile_rows, &rb, backend, &mut got,
                    );
                    assert_eq!(
                        got[..tile_rows],
                        want[..tile_rows],
                        "ord {} t{t} width {tile_rows}",
                        backend.name()
                    );
                    walk_tile_branchy::<F32Domain>(
                        &trees_f32, t, &ds.features, 0, tile_rows, &mut want,
                    );
                    walk_tile_predicated::<F32Domain>(
                        &trees_f32, t, &ds.features, 0, tile_rows, &rb, backend, &mut got,
                    );
                    assert_eq!(
                        got[..tile_rows],
                        want[..tile_rows],
                        "f32 {} t{t} width {tile_rows}",
                        backend.name()
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of n_features")]
    fn ragged_batch_rejected() {
        let f = forest();
        int_fixed_batch(&f, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn kernel_names() {
        assert_eq!(TraversalKernel::all().len(), 3);
        assert_eq!(TraversalKernel::Branchy.name(), "branchy");
        assert_eq!(TraversalKernel::Branchless.name(), "branchless");
        assert_eq!(TraversalKernel::QuickScorer.name(), "quickscorer");
        assert_eq!(TraversalKernel::default(), TraversalKernel::Branchless);
    }

    /// The ragged-tail fix (satellite): the duplicated-lane lockstep tail
    /// must agree with the branchy walker lane for lane at every tail
    /// width 1..TILE_ROWS.
    #[test]
    fn lockstep_tail_matches_branchy_at_every_width() {
        let f = forest();
        let ds = shuttle_like(64, 24);
        let rows_ord: Vec<u32> = ds.features.iter().map(|&x| ordered_u32(x)).collect();
        let trees_ord = f.packed_ord();
        let mut leaves_branchy = [0u32; TILE_ROWS];
        let mut leaves_tail = [0u32; TILE_ROWS];
        for tile_rows in 1..=TILE_ROWS {
            for t in 0..f.n_trees {
                walk_tile_branchy::<OrdDomain>(
                    &trees_ord, t, &rows_ord, 0, tile_rows, &mut leaves_branchy,
                );
                walk_tile_lockstep_tail::<OrdDomain>(
                    &trees_ord, t, &rows_ord, 0, tile_rows, &mut leaves_tail,
                );
                assert_eq!(
                    leaves_tail[..tile_rows],
                    leaves_branchy[..tile_rows],
                    "t{t} width {tile_rows}"
                );
            }
        }
    }

    #[test]
    fn argmax_and_split_helpers() {
        let flat = vec![1u32, 5, 2, 9, 0, 0];
        assert_eq!(argmax_rows(&flat, 3), vec![1, 0]);
        assert_eq!(split_rows(flat, 3), vec![vec![1, 5, 2], vec![9, 0, 0]]);
    }

    #[test]
    fn ordered_row_scratch_reusable_and_reentrant() {
        let row = [1.0f32, -2.0, 3.0];
        let out = with_ordered_row(&row, |a| {
            // Re-entrant use must not alias the outer buffer.
            let inner = with_ordered_row(&[4.0f32], |b| b.to_vec());
            assert_eq!(inner, vec![ordered_u32(4.0)]);
            a.to_vec()
        });
        let want: Vec<u32> = row.iter().map(|&x| ordered_u32(x)).collect();
        assert_eq!(out, want);
        // Second call reuses the (restored) scratch.
        let out2 = with_ordered_row(&row, |a| a.to_vec());
        assert_eq!(out2, want);
    }
}
