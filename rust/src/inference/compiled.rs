//! Compiled forest layout shared by the three engines.
//!
//! Two layouts coexist:
//! * **SoA columns** (`feature`/`thresh_*`/`left`/`right`) — the
//!   analysis-friendly form used by the simulator tracer and the XLA
//!   packer ([`crate::runtime`]). Leaves keep the [`LEAF`] sentinel and
//!   an explicit `right` column here.
//! * **AoS hot nodes** ([`Node8`], 8 bytes each) — the traversal hot
//!   path. A tree walk touches nodes in a random pattern; packing
//!   `(threshold, feature, left-child)` into one 8-byte struct doubles
//!   the nodes per cache line over the seed's 16-byte form (§Perf in
//!   `DESIGN.md`). The `right` pointer is gone entirely: every compiled
//!   tree is canonicalized to the **child-adjacent** encoding
//!   (`right = left + 1` always), so one index plus the comparison bit
//!   addresses both children — `next = left + (x > threshold)` — which
//!   is the arithmetic, predicated descent the branchless batch kernel
//!   ([`super::batch`]) is built on.
//!
//! ## The 8-byte node encoding
//!
//! | field | branch                         | leaf                        |
//! |-------|--------------------------------|-----------------------------|
//! | `tw`  | threshold word (see below)     | payload row index           |
//! | `ff`  | feature index (bit 15 clear)   | [`LEAF_BIT`] (feature bits 0)|
//! | `left`| tree-local left-child index    | tree-local **own** index    |
//!
//! `tw` holds the ordered-u32 threshold in `nodes_ord` and the raw f32
//! bits in `nodes_f32`. Leaves **self-loop**: `left` points at the leaf
//! itself and the descent step is masked to zero by the leaf bit, so a
//! lane that reaches its leaf early simply parks there while the other
//! lanes keep walking — the trick that lets the batch kernel run a
//! fixed, data-independent trip count (`tree_depths[t]`) with no
//! leaf-sentinel branch. The payload rides in the threshold slot, which
//! a parked lane never meaningfully compares against (the compare still
//! executes, but its result is masked by the leaf bit).

use super::quickscorer::QsPlan;
use crate::flint::ordered_u32;
use crate::ir::{Model, ModelKind, Node};
use crate::quant::prob_to_fixed;
use std::collections::VecDeque;

/// Sentinel feature index marking a leaf node (SoA columns only; the
/// packed [`Node8`] form uses [`LEAF_BIT`]).
pub const LEAF: u32 = u32::MAX;

/// Leaf flag bit of [`Node8::ff`].
pub const LEAF_BIT: u16 = 0x8000;

/// Mask selecting the feature-index bits of [`Node8::ff`].
pub const FEATURE_MASK: u16 = 0x7FFF;

/// Maximum feature count the packed encoding supports (15 index bits).
pub const MAX_FEATURES: usize = FEATURE_MASK as usize + 1;

/// Maximum nodes per tree the packed encoding supports (`left` is u16).
pub const MAX_TREE_NODES: usize = u16::MAX as usize + 1;

/// In-memory node ordering of a compiled tree, selected at compile time.
///
/// Both orders produce *identical predictions* (the permutation remaps
/// child indices consistently and leaf payloads are untouched); they only
/// change which cache lines a traversal touches. Both are canonicalized
/// to the child-adjacent form (`right = left + 1` for every branch):
///
/// * [`NodeOrder::Depth`] — pair-packed pre-order DFS: both children of
///   a branch are allocated together, then the left subtree is laid out
///   before the right one. Left spines land at stride 2, so strongly
///   left-leaning paths stream well.
/// * [`NodeOrder::Breadth`] — BFS level order (naturally child-adjacent).
///   The first few levels of every tree — the nodes *every* row visits —
///   pack into the first cache lines of the tree's range, which is the
///   better layout for the tiled batch kernel where R rows walk the same
///   tree in lockstep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum NodeOrder {
    /// Pair-packed pre-order DFS.
    #[default]
    Depth,
    /// BFS level order (hot upper levels first).
    Breadth,
}

impl NodeOrder {
    /// Display name of the ordering.
    pub fn name(self) -> &'static str {
        match self {
            NodeOrder::Depth => "depth",
            NodeOrder::Breadth => "breadth",
        }
    }

    /// Both orderings (layout sweeps iterate this).
    pub fn all() -> [NodeOrder; 2] {
        [NodeOrder::Depth, NodeOrder::Breadth]
    }
}

/// Packed 8-byte hot-path node (see the module docs for the encoding).
/// One cache line holds eight of them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(C)]
pub struct Node8 {
    /// Branch: threshold word (ordered-u32 or f32 bits, by array).
    /// Leaf: payload row index into `leaf_f32` / `leaf_u32`.
    pub tw: u32,
    /// Feature index ([`FEATURE_MASK`] bits) | [`LEAF_BIT`].
    pub ff: u16,
    /// Branch: tree-local left-child index (`right = left + 1`).
    /// Leaf: tree-local own index (self-loop).
    pub left: u16,
}

// The whole point of the encoding — a regression here silently halves
// cache density, so it is a compile error, not a bench note.
const _: () = assert!(std::mem::size_of::<Node8>() == 8, "Node8 must stay 8 bytes");
const _: () = assert!(std::mem::align_of::<Node8>() == 4, "Node8 must stay 4-byte aligned");

/// Ordered-u32-threshold node array element (FlInt / InTreeger walks).
pub type NodeOrd = Node8;
/// f32-bits-threshold node array element (float baseline walks).
pub type NodeF32 = Node8;

impl Node8 {
    /// Whether this node is a leaf (tests [`LEAF_BIT`]).
    #[inline(always)]
    pub fn is_leaf(self) -> bool {
        self.ff & LEAF_BIT != 0
    }

    /// Feature index to load (leaves read feature 0, harmlessly — the
    /// descent step is masked by [`Self::branch_mask`]).
    #[inline(always)]
    pub fn feature_index(self) -> usize {
        (self.ff & FEATURE_MASK) as usize
    }

    /// 1 for a branch, 0 for a leaf — the predication mask of the
    /// branchless descent step `left + ((x > tw) & branch_mask)`.
    #[inline(always)]
    pub fn branch_mask(self) -> u32 {
        (self.ff >> 15) as u32 ^ 1
    }

    /// The SIMD gather-plane word packing `ff` (low 16 bits) and `left`
    /// (high 16 bits) — the single definition of the `soa_ffl` encoding,
    /// shared by the plane builder ([`soa_planes`]) and the binary-format
    /// validator ([`crate::runtime::binfmt`]), which re-checks stored
    /// planes against it before any kernel trusts them.
    #[inline(always)]
    pub fn ffl_word(self) -> u32 {
        (self.ff as u32) | ((self.left as u32) << 16)
    }
}

/// One forest compiled to flat arrays.
///
/// For node `i` of tree `t` (indices into the per-tree range
/// `tree_offsets[t] .. tree_offsets[t+1]`), in the SoA columns:
/// * `feature[i] == LEAF` → leaf; `left[i]` is the index of its payload
///   row (length `n_classes`) in `leaf_f32` / `leaf_u32`.
/// * otherwise → branch on `feature[i]` with children `left[i]`/`right[i]`
///   (tree-local indices), threshold available in all encodings — and
///   `right[i] == left[i] + 1` always (the child-adjacent canonical form).
///
/// The AoS arrays `nodes_f32`/`nodes_ord` use the same node indexing with
/// the packed 8-byte encoding.
#[derive(Clone, Debug)]
pub struct CompiledForest {
    /// Feature columns the model consumes.
    pub n_features: usize,
    /// Classes the model predicts.
    pub n_classes: usize,
    /// Trees in the forest.
    pub n_trees: usize,
    /// Start index of each tree's nodes; length `n_trees + 1`.
    pub tree_offsets: Vec<u32>,
    /// Maximum root-to-leaf depth of each tree — the fixed trip count of
    /// the branchless batch kernel; length `n_trees`.
    pub tree_depths: Vec<u32>,
    /// SoA column: split feature per node ([`LEAF`] marks leaves).
    pub feature: Vec<u32>,
    /// Threshold as f32 (float engine).
    pub thresh_f32: Vec<f32>,
    /// Threshold order-preserving-mapped to u32 (FlInt / InTreeger engines).
    pub thresh_ord: Vec<u32>,
    /// SoA column: left child (branches) / payload row (leaves).
    pub left: Vec<u32>,
    /// SoA column: right child (always `left + 1` for branches).
    pub right: Vec<u32>,
    /// Leaf probabilities, row-major `n_leaves * n_classes` (float engines).
    pub leaf_f32: Vec<f32>,
    /// Leaf fixed-point values with scale `2^32/n_trees` (integer engine).
    pub leaf_u32: Vec<u32>,
    /// Packed AoS hot nodes, f32-bits thresholds (same indexing as SoA).
    pub nodes_f32: Vec<NodeF32>,
    /// Packed AoS hot nodes, ordered-u32 thresholds.
    pub nodes_ord: Vec<NodeOrd>,
    /// SIMD gather plane mirroring `nodes_ord[i].tw` (ordered-u32
    /// threshold word / leaf payload per node). Built once at compile
    /// time alongside the packed arrays and asserted consistent; the
    /// `vpgatherdd`-based AVX2 walkers ([`super::simd`]) fetch nodes
    /// from these u32 planes instead of the 8-byte AoS structs.
    pub soa_tw_ord: Vec<u32>,
    /// SIMD gather plane mirroring `nodes_f32[i].tw` (raw f32 bits).
    pub soa_tw_f32: Vec<u32>,
    /// SIMD gather plane packing `nodes_*[i].ff` (low 16 bits: feature |
    /// [`LEAF_BIT`]) and `nodes_*[i].left` (high 16 bits) into one u32
    /// word — identical for both threshold domains, asserted so.
    pub soa_ffl: Vec<u32>,
    /// Node layout this forest was compiled with.
    pub order: NodeOrder,
    /// QuickScorer condition-stream plan (the bitvector kernel; built for
    /// every forest — selecting it is a runtime [`super::TraversalKernel`]
    /// choice, and ineligible trees carry their walker fallback here).
    pub qs: QsPlan,
}

/// Child-adjacent permutation of one tree (tree-local SoA slices):
/// returns `order` with `order[new] = old` such that for every branch the
/// two children land on consecutive new indices (left first).
///
/// Relies on the proper-tree shape `Model::validate()` guarantees (every
/// node reachable from the root through exactly one parent): each node is
/// then assigned exactly one slot.
pub(crate) fn child_adjacent_order(
    feature: &[u32],
    left: &[u32],
    right: &[u32],
    order: NodeOrder,
) -> Vec<u32> {
    let n = feature.len();
    match order {
        // BFS: children are enqueued back-to-back, so they pop (and get
        // numbered) consecutively.
        NodeOrder::Breadth => {
            let mut out: Vec<u32> = Vec::with_capacity(n);
            let mut queue: VecDeque<u32> = VecDeque::with_capacity(n);
            queue.push_back(0);
            while let Some(old) = queue.pop_front() {
                out.push(old);
                if feature[old as usize] != LEAF {
                    queue.push_back(left[old as usize]);
                    queue.push_back(right[old as usize]);
                }
            }
            assert_eq!(out.len(), n, "child_adjacent_order: tree is not a proper tree");
            out
        }
        // Pair-packed DFS: both child slots are allocated when their
        // parent is visited; the left subtree is then visited (and keeps
        // allocating) before the right one.
        NodeOrder::Depth => {
            let mut out = vec![u32::MAX; n];
            out[0] = 0;
            let mut next = 1usize;
            let mut stack: Vec<u32> = vec![0];
            while let Some(old) = stack.pop() {
                if feature[old as usize] != LEAF {
                    let (l, r) = (left[old as usize], right[old as usize]);
                    assert!(next + 2 <= n, "child_adjacent_order: tree is not a proper tree");
                    out[next] = l;
                    out[next + 1] = r;
                    next += 2;
                    stack.push(r);
                    stack.push(l);
                }
            }
            assert_eq!(next, n, "child_adjacent_order: tree is not a proper tree");
            out
        }
    }
}

/// Pack one tree's tree-local SoA columns straight into child-adjacent
/// [`Node8`]s — the canonical encoding, shared by the RF and GBT
/// compilers so the leaf-self-loop / payload-in-`tw` invariants live in
/// exactly one place. `thresh_words` carries the 32-bit threshold
/// encoding of the caller's domain (ordered-u32 or f32 bits); `left[i]`
/// of a [`LEAF`] row must already hold the payload index.
pub(crate) fn pack_tree(
    feature: &[u32],
    thresh_words: &[u32],
    left: &[u32],
    right: &[u32],
    order: NodeOrder,
) -> Vec<Node8> {
    let order_vec = child_adjacent_order(feature, left, right, order);
    let n = order_vec.len();
    let mut new_of = vec![0u32; n];
    for (new, &old) in order_vec.iter().enumerate() {
        new_of[old as usize] = new as u32;
    }
    let mut out = Vec::with_capacity(n);
    for (new, &old) in order_vec.iter().enumerate() {
        let i = old as usize;
        if feature[i] == LEAF {
            out.push(Node8 { tw: left[i], ff: LEAF_BIT, left: new as u16 });
        } else {
            let l = new_of[left[i] as usize];
            debug_assert_eq!(
                new_of[right[i] as usize],
                l + 1,
                "layout pass lost child adjacency"
            );
            out.push(Node8 { tw: thresh_words[i], ff: feature[i] as u16, left: l as u16 });
        }
    }
    out
}

/// Build the SIMD gather planes of a packed node array: the `tw` words
/// and the `ff | left << 16` words, one u32 each per node (see the
/// `CompiledForest::soa_*` field docs). Shared by the RF and GBT
/// compilers so the plane encoding lives in exactly one place.
pub(crate) fn soa_planes(nodes: &[Node8]) -> (Vec<u32>, Vec<u32>) {
    let tw = nodes.iter().map(|n| n.tw).collect();
    let ffl = nodes.iter().map(|n| n.ffl_word()).collect();
    (tw, ffl)
}

impl CompiledForest {
    /// Compile with the default (depth-first) node order.
    /// Panics on GBT models (use [`crate::inference::GbtIntEngine`]).
    pub fn compile(model: &Model) -> CompiledForest {
        Self::compile_with(model, NodeOrder::Depth)
    }

    /// Compile a random-forest IR model into the flat layout with an
    /// explicit node order. Either order is canonicalized to the
    /// child-adjacent form (see [`NodeOrder`]).
    pub fn compile_with(model: &Model, order: NodeOrder) -> CompiledForest {
        assert_eq!(model.kind, ModelKind::RandomForest, "CompiledForest requires an RF model");
        model.validate().expect("model must be valid");
        assert!(
            model.n_features <= MAX_FEATURES,
            "packed node encoding supports at most {MAX_FEATURES} features, model has {}",
            model.n_features
        );
        let n_trees = model.trees.len();

        let mut out = CompiledForest {
            n_features: model.n_features,
            n_classes: model.n_classes,
            n_trees,
            tree_offsets: Vec::with_capacity(n_trees + 1),
            tree_depths: model.trees.iter().map(|t| t.depth() as u32).collect(),
            feature: Vec::new(),
            thresh_f32: Vec::new(),
            thresh_ord: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
            leaf_f32: Vec::new(),
            leaf_u32: Vec::new(),
            nodes_f32: Vec::new(),
            nodes_ord: Vec::new(),
            soa_tw_ord: Vec::new(),
            soa_tw_f32: Vec::new(),
            soa_ffl: Vec::new(),
            order,
            qs: QsPlan::build(model),
        };

        for tree in &model.trees {
            assert!(
                tree.nodes.len() <= MAX_TREE_NODES,
                "packed node encoding supports at most {MAX_TREE_NODES} nodes per tree, tree has {}",
                tree.nodes.len()
            );
            out.tree_offsets.push(out.feature.len() as u32);
            for node in &tree.nodes {
                match node {
                    Node::Branch { feature, threshold, left, right } => {
                        out.feature.push(*feature);
                        out.thresh_f32.push(*threshold);
                        out.thresh_ord.push(ordered_u32(*threshold));
                        out.left.push(*left);
                        out.right.push(*right);
                    }
                    Node::Leaf { values } => {
                        let payload = (out.leaf_f32.len() / model.n_classes) as u32;
                        out.feature.push(LEAF);
                        out.thresh_f32.push(0.0);
                        out.thresh_ord.push(0);
                        out.left.push(payload);
                        out.right.push(0);
                        out.leaf_f32.extend_from_slice(values);
                        out.leaf_u32.extend(values.iter().map(|&p| prob_to_fixed(p, n_trees)));
                    }
                }
            }
        }
        out.tree_offsets.push(out.feature.len() as u32);
        out.canonicalize_child_adjacent();
        out.build_packed();
        out
    }

    /// Total node count.
    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// Permute every tree's SoA columns into the child-adjacent form of
    /// [`Self::order`].
    ///
    /// Branch child indices are remapped through the permutation; leaf
    /// payload indices (`left` of a LEAF node) address the leaf arrays,
    /// not nodes, and are carried over untouched — so traversal reaches
    /// bit-identical leaf payloads in either order. The root keeps local
    /// index 0, which `walk_*` relies on.
    fn canonicalize_child_adjacent(&mut self) {
        for t in 0..self.n_trees {
            let lo = self.tree_offsets[t] as usize;
            let hi = self.tree_offsets[t + 1] as usize;
            let n = hi - lo;
            if n <= 1 {
                continue;
            }
            let order = child_adjacent_order(
                &self.feature[lo..hi],
                &self.left[lo..hi],
                &self.right[lo..hi],
                self.order,
            );
            // new_of[old] = new (tree-local indices).
            let mut new_of = vec![0u32; n];
            for (new, &old) in order.iter().enumerate() {
                new_of[old as usize] = new as u32;
            }
            let mut feature = Vec::with_capacity(n);
            let mut thresh_f32 = Vec::with_capacity(n);
            let mut thresh_ord = Vec::with_capacity(n);
            let mut left = Vec::with_capacity(n);
            let mut right = Vec::with_capacity(n);
            for &old in &order {
                let i = lo + old as usize;
                feature.push(self.feature[i]);
                thresh_f32.push(self.thresh_f32[i]);
                thresh_ord.push(self.thresh_ord[i]);
                if self.feature[i] == LEAF {
                    left.push(self.left[i]);
                    right.push(self.right[i]);
                } else {
                    let l = new_of[self.left[i] as usize];
                    let r = new_of[self.right[i] as usize];
                    debug_assert_eq!(r, l + 1, "layout pass lost child adjacency");
                    left.push(l);
                    right.push(r);
                }
            }
            self.feature[lo..hi].copy_from_slice(&feature);
            self.thresh_f32[lo..hi].copy_from_slice(&thresh_f32);
            self.thresh_ord[lo..hi].copy_from_slice(&thresh_ord);
            self.left[lo..hi].copy_from_slice(&left);
            self.right[lo..hi].copy_from_slice(&right);
        }
    }

    /// Build the packed 8-byte AoS arrays from the (canonicalized) SoA
    /// columns, through the one shared [`pack_tree`] encoder. The SoA is
    /// already child-adjacent, so the permutation `pack_tree` derives is
    /// the identity (the layout pass is a deterministic fixed point) and
    /// AoS/SoA indexing stays aligned.
    fn build_packed(&mut self) {
        let n = self.feature.len();
        self.nodes_f32 = Vec::with_capacity(n);
        self.nodes_ord = Vec::with_capacity(n);
        for t in 0..self.n_trees {
            let lo = self.tree_offsets[t] as usize;
            let hi = self.tree_offsets[t + 1] as usize;
            let f32_words: Vec<u32> =
                self.thresh_f32[lo..hi].iter().map(|x| x.to_bits()).collect();
            let ord = pack_tree(
                &self.feature[lo..hi],
                &self.thresh_ord[lo..hi],
                &self.left[lo..hi],
                &self.right[lo..hi],
                self.order,
            );
            let f32n = pack_tree(
                &self.feature[lo..hi],
                &f32_words,
                &self.left[lo..hi],
                &self.right[lo..hi],
                self.order,
            );
            self.nodes_ord.extend(ord);
            self.nodes_f32.extend(f32n);
        }
        // SIMD gather planes: a u32-per-node mirror of the packed
        // arrays, built once here. The ff/left halves must agree across
        // the two threshold domains (one shared ffl plane serves both) —
        // asserted, not assumed, since a divergence would silently route
        // SIMD lanes differently from the scalar walkers.
        let (tw_ord, ffl_ord) = soa_planes(&self.nodes_ord);
        let (tw_f32, ffl_f32) = soa_planes(&self.nodes_f32);
        assert_eq!(ffl_ord, ffl_f32, "ord/f32 packed arrays disagree on ff/left");
        self.soa_tw_ord = tw_ord;
        self.soa_tw_f32 = tw_f32;
        self.soa_ffl = ffl_ord;
    }

    /// Walk tree `t` on a raw float row, returning the leaf payload index.
    ///
    /// SAFETY of the unchecked indexing: `Model::validate()` (enforced at
    /// compile time) guarantees child indices stay inside the tree and
    /// feature indices stay below `n_features`; callers pass rows of at
    /// least `n_features` values (asserted here once, not per node).
    #[inline]
    pub fn walk_f32(&self, t: usize, row: &[f32]) -> u32 {
        assert!(row.len() >= self.n_features);
        let base = self.tree_offsets[t] as usize;
        let nodes = &self.nodes_f32;
        let mut i = base;
        loop {
            let n = unsafe { *nodes.get_unchecked(i) };
            if n.is_leaf() {
                return n.tw;
            }
            // Literal negation of `<=`-goes-left (not `>`): identical for
            // finite values, and preserves the seed's NaN routing for
            // out-of-contract inputs (NaN fails both compares).
            let go_right =
                !(unsafe { *row.get_unchecked(n.feature_index()) } <= f32::from_bits(n.tw));
            i = base + n.left as usize + go_right as usize;
        }
    }

    /// Walk tree `t` on an ordered-u32 transformed row (same safety
    /// argument as [`Self::walk_f32`]).
    #[inline]
    pub fn walk_ord(&self, t: usize, row_ord: &[u32]) -> u32 {
        assert!(row_ord.len() >= self.n_features);
        let base = self.tree_offsets[t] as usize;
        let nodes = &self.nodes_ord;
        let mut i = base;
        loop {
            let n = unsafe { *nodes.get_unchecked(i) };
            if n.is_leaf() {
                return n.tw;
            }
            let go_right = unsafe { *row_ord.get_unchecked(n.feature_index()) } > n.tw;
            i = base + n.left as usize + go_right as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> Model {
        let ds = shuttle_like(1500, 1);
        RandomForest::train(&ds, &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() }, 3)
    }

    #[test]
    fn compile_shapes() {
        let m = model();
        let c = CompiledForest::compile(&m);
        assert_eq!(c.n_trees, 6);
        assert_eq!(c.tree_offsets.len(), 7);
        assert_eq!(c.tree_depths.len(), 6);
        assert_eq!(c.n_nodes(), m.n_nodes());
        assert_eq!(c.leaf_f32.len(), m.n_leaves() * m.n_classes);
        assert_eq!(c.leaf_u32.len(), c.leaf_f32.len());
        assert_eq!(c.feature.len(), c.thresh_f32.len());
        assert_eq!(c.feature.len(), c.left.len());
        assert_eq!(c.nodes_f32.len(), c.n_nodes());
        assert_eq!(c.nodes_ord.len(), c.n_nodes());
        for (t, tree) in m.trees.iter().enumerate() {
            assert_eq!(c.tree_depths[t] as usize, tree.depth());
        }
    }

    #[test]
    fn node8_is_8_bytes() {
        assert_eq!(std::mem::size_of::<Node8>(), 8);
        assert_eq!(std::mem::size_of::<NodeOrd>(), 8);
        assert_eq!(std::mem::size_of::<NodeF32>(), 8);
    }

    /// The canonical invariant of the compiled form: every branch's
    /// children are adjacent (`right == left + 1`) in both orders, every
    /// packed leaf self-loops carrying its payload in `tw`, and SoA/AoS
    /// agree node-for-node.
    #[test]
    fn child_adjacent_invariant_both_orders() {
        let m = model();
        for order in NodeOrder::all() {
            let c = CompiledForest::compile_with(&m, order);
            for t in 0..c.n_trees {
                let lo = c.tree_offsets[t] as usize;
                let hi = c.tree_offsets[t + 1] as usize;
                for i in lo..hi {
                    let local = (i - lo) as u32;
                    if c.feature[i] == LEAF {
                        for nodes in [&c.nodes_f32, &c.nodes_ord] {
                            assert!(nodes[i].is_leaf());
                            assert_eq!(nodes[i].tw, c.left[i], "payload in tw");
                            assert_eq!(nodes[i].left as u32, local, "leaf self-loop");
                            assert_eq!(nodes[i].branch_mask(), 0);
                            assert_eq!(nodes[i].feature_index(), 0, "leaf reads feature 0");
                        }
                    } else {
                        assert_eq!(c.right[i], c.left[i] + 1, "{order:?} tree {t} node {local}");
                        // Both children inside the tree — the implied
                        // right child (left + 1) is the bound the
                        // unchecked walker indexing relies on.
                        assert!((c.left[i] as usize) + 1 < hi - lo, "children inside tree");
                        for nodes in [&c.nodes_f32, &c.nodes_ord] {
                            assert!(!nodes[i].is_leaf());
                            assert_eq!(nodes[i].branch_mask(), 1);
                            assert_eq!(nodes[i].feature_index() as u32, c.feature[i]);
                            assert_eq!(nodes[i].left as u32, c.left[i]);
                        }
                        assert_eq!(c.nodes_ord[i].tw, c.thresh_ord[i]);
                        assert_eq!(f32::from_bits(c.nodes_f32[i].tw), c.thresh_f32[i]);
                    }
                }
            }
        }
    }

    /// The SIMD gather planes are an exact mirror of the packed Node8
    /// arrays: `tw` word for word, and `ffl` packing ff (low 16) and
    /// left (high 16) — the decode the intrinsic walkers perform
    /// (`feature = ffl & 0x7FFF`, `leaf = (ffl >> 15) & 1`,
    /// `left = ffl >> 16`) must recover the scalar walkers' fields.
    #[test]
    fn soa_planes_mirror_packed_nodes() {
        let m = model();
        for order in NodeOrder::all() {
            let c = CompiledForest::compile_with(&m, order);
            assert_eq!(c.soa_tw_ord.len(), c.n_nodes());
            assert_eq!(c.soa_tw_f32.len(), c.n_nodes());
            assert_eq!(c.soa_ffl.len(), c.n_nodes());
            for i in 0..c.n_nodes() {
                assert_eq!(c.soa_tw_ord[i], c.nodes_ord[i].tw);
                assert_eq!(c.soa_tw_f32[i], c.nodes_f32[i].tw);
                let ffl = c.soa_ffl[i];
                assert_eq!((ffl & 0x7FFF) as usize, c.nodes_ord[i].feature_index());
                assert_eq!((ffl >> 15) & 1, 1 - c.nodes_ord[i].branch_mask());
                assert_eq!(ffl >> 16, c.nodes_ord[i].left as u32);
            }
        }
    }

    #[test]
    fn walks_agree_with_ir_eval() {
        let m = model();
        let c = CompiledForest::compile(&m);
        let ds = shuttle_like(200, 2);
        for i in 0..ds.n_rows() {
            let row = ds.row(i);
            let row_ord: Vec<u32> = row.iter().map(|&x| ordered_u32(x)).collect();
            for t in 0..c.n_trees {
                let leaf_ir = m.trees[t].evaluate(row);
                let pf = c.walk_f32(t, row) as usize;
                let po = c.walk_ord(t, &row_ord) as usize;
                assert_eq!(pf, po, "float and flint walks disagree");
                let got = &c.leaf_f32[pf * c.n_classes..(pf + 1) * c.n_classes];
                assert_eq!(got, leaf_ir);
            }
        }
    }

    #[test]
    fn breadth_order_reaches_identical_leaves() {
        let m = model();
        let depth = CompiledForest::compile_with(&m, NodeOrder::Depth);
        let breadth = CompiledForest::compile_with(&m, NodeOrder::Breadth);
        assert_eq!(depth.order, NodeOrder::Depth);
        assert_eq!(breadth.order, NodeOrder::Breadth);
        assert_eq!(depth.n_nodes(), breadth.n_nodes());
        // Same leaf arrays (payloads are not permuted)...
        assert_eq!(depth.leaf_f32, breadth.leaf_f32);
        assert_eq!(depth.leaf_u32, breadth.leaf_u32);
        // ...but a genuinely different node ordering somewhere (pair-packed
        // DFS and BFS diverge once some depth-2 node has grandchildren).
        assert_ne!(
            (&depth.feature, &depth.left),
            (&breadth.feature, &breadth.left),
            "reorder was a no-op on a depth-6 forest"
        );
        let ds = shuttle_like(300, 5);
        for i in 0..ds.n_rows() {
            let row = ds.row(i);
            let row_ord: Vec<u32> = row.iter().map(|&x| ordered_u32(x)).collect();
            for t in 0..depth.n_trees {
                assert_eq!(depth.walk_f32(t, row), breadth.walk_f32(t, row));
                assert_eq!(depth.walk_ord(t, &row_ord), breadth.walk_ord(t, &row_ord));
            }
        }
    }

    #[test]
    fn both_orders_pack_roots_children_first() {
        // Child-adjacent canonical form: the root's children occupy local
        // slots 1 and 2 in *both* orders (pairs are allocated root-first).
        let m = model();
        for order in NodeOrder::all() {
            let c = CompiledForest::compile_with(&m, order);
            for t in 0..c.n_trees {
                let lo = c.tree_offsets[t] as usize;
                if c.feature[lo] == LEAF {
                    continue; // single-node tree
                }
                assert_eq!(c.left[lo], 1, "tree {t}: root's left child at slot 1");
                assert_eq!(c.right[lo], 2, "tree {t}: root's right child at slot 2");
            }
        }
    }

    #[test]
    #[should_panic(expected = "RF model")]
    fn rejects_gbt() {
        let mut m = model();
        m.kind = ModelKind::Gbt;
        CompiledForest::compile(&m);
    }
}
