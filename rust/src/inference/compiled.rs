//! Compiled forest layout shared by the three engines.
//!
//! Two layouts coexist:
//! * **SoA columns** (`feature`/`thresh_*`/`left`/`right`) — the
//!   analysis-friendly form used by the simulator tracer and the XLA
//!   packer ([`crate::runtime`]).
//! * **AoS hot nodes** ([`NodeF32`]/[`NodeOrd`], 16 bytes each) — the
//!   traversal hot path. A branchy tree walk touches nodes in a random
//!   pattern; packing `(feature, threshold, left, right)` into one
//!   16-byte struct means each visited node costs a single cache line
//!   instead of four (§Perf: this alone bought ~2.4x on the 50-tree
//!   shuttle model).

use crate::flint::ordered_u32;
use crate::ir::{Model, ModelKind, Node};
use crate::quant::prob_to_fixed;
use std::collections::VecDeque;

/// Sentinel feature index marking a leaf node.
pub const LEAF: u32 = u32::MAX;

/// In-memory node ordering of a compiled tree, selected at compile time.
///
/// Both orders produce *identical predictions* (the permutation remaps
/// child indices consistently and leaf payloads are untouched); they only
/// change which cache lines a traversal touches:
///
/// * [`NodeOrder::Depth`] — the IR emission order (pre-order DFS). Left
///   spines are contiguous, so strongly left-leaning paths stream well.
/// * [`NodeOrder::Breadth`] — BFS level order. The first few levels of
///   every tree — the nodes *every* row visits — pack into the first
///   cache lines of the tree's range, which is the better layout for the
///   tiled batch kernel where R rows walk the same tree in lockstep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum NodeOrder {
    /// Pre-order DFS (the seed layout).
    #[default]
    Depth,
    /// BFS level order (hot upper levels first).
    Breadth,
}

impl NodeOrder {
    pub fn name(self) -> &'static str {
        match self {
            NodeOrder::Depth => "depth",
            NodeOrder::Breadth => "breadth",
        }
    }

    pub fn all() -> [NodeOrder; 2] {
        [NodeOrder::Depth, NodeOrder::Breadth]
    }
}

/// Hot-path node, float-threshold form (one cache-line-quarter).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct NodeF32 {
    pub feature: u32,
    pub threshold: f32,
    /// Branch: tree-local child index. Leaf: payload row index.
    pub left: u32,
    pub right: u32,
}

/// Hot-path node, ordered-u32-threshold form (FlInt/InTreeger walks).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct NodeOrd {
    pub feature: u32,
    pub threshold: u32,
    pub left: u32,
    pub right: u32,
}

/// One forest compiled to flat arrays.
///
/// For node `i` of tree `t` (indices into the per-tree range
/// `tree_offsets[t] .. tree_offsets[t+1]`):
/// * `feature[i] == LEAF` → leaf; `left[i]` is the index of its payload
///   row (length `n_classes`) in `leaf_f32` / `leaf_u32`.
/// * otherwise → branch on `feature[i]` with children `left[i]`/`right[i]`
///   (tree-local indices), threshold available in all three encodings.
#[derive(Clone, Debug)]
pub struct CompiledForest {
    pub n_features: usize,
    pub n_classes: usize,
    pub n_trees: usize,
    /// Start index of each tree's nodes; length `n_trees + 1`.
    pub tree_offsets: Vec<u32>,
    pub feature: Vec<u32>,
    /// Threshold as f32 (float engine).
    pub thresh_f32: Vec<f32>,
    /// Threshold order-preserving-mapped to u32 (FlInt / InTreeger engines).
    pub thresh_ord: Vec<u32>,
    pub left: Vec<u32>,
    pub right: Vec<u32>,
    /// Leaf probabilities, row-major `n_leaves * n_classes` (float engines).
    pub leaf_f32: Vec<f32>,
    /// Leaf fixed-point values with scale `2^32/n_trees` (integer engine).
    pub leaf_u32: Vec<u32>,
    /// AoS hot nodes (same indexing as the SoA columns).
    pub nodes_f32: Vec<NodeF32>,
    /// AoS hot nodes with order-preserved thresholds.
    pub nodes_ord: Vec<NodeOrd>,
    /// Node layout this forest was compiled with.
    pub order: NodeOrder,
}

impl CompiledForest {
    /// Compile with the default (depth-first) node order.
    /// Panics on GBT models (use [`crate::inference::GbtIntEngine`]).
    pub fn compile(model: &Model) -> CompiledForest {
        Self::compile_with(model, NodeOrder::Depth)
    }

    /// Compile a random-forest IR model into the flat layout with an
    /// explicit node order.
    pub fn compile_with(model: &Model, order: NodeOrder) -> CompiledForest {
        assert_eq!(model.kind, ModelKind::RandomForest, "CompiledForest requires an RF model");
        model.validate().expect("model must be valid");
        let n_trees = model.trees.len();

        let mut out = CompiledForest {
            n_features: model.n_features,
            n_classes: model.n_classes,
            n_trees,
            tree_offsets: Vec::with_capacity(n_trees + 1),
            feature: Vec::new(),
            thresh_f32: Vec::new(),
            thresh_ord: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
            leaf_f32: Vec::new(),
            leaf_u32: Vec::new(),
            nodes_f32: Vec::new(),
            nodes_ord: Vec::new(),
            order,
        };

        for tree in &model.trees {
            out.tree_offsets.push(out.feature.len() as u32);
            for node in &tree.nodes {
                match node {
                    Node::Branch { feature, threshold, left, right } => {
                        out.feature.push(*feature);
                        out.thresh_f32.push(*threshold);
                        out.thresh_ord.push(ordered_u32(*threshold));
                        out.left.push(*left);
                        out.right.push(*right);
                    }
                    Node::Leaf { values } => {
                        let payload = (out.leaf_f32.len() / model.n_classes) as u32;
                        out.feature.push(LEAF);
                        out.thresh_f32.push(0.0);
                        out.thresh_ord.push(0);
                        out.left.push(payload);
                        out.right.push(0);
                        out.leaf_f32.extend_from_slice(values);
                        out.leaf_u32.extend(values.iter().map(|&p| prob_to_fixed(p, n_trees)));
                    }
                }
            }
        }
        out.tree_offsets.push(out.feature.len() as u32);
        if order == NodeOrder::Breadth {
            out.reorder_breadth_first();
        }
        // Build the AoS hot nodes from the SoA columns.
        out.nodes_f32 = (0..out.feature.len())
            .map(|i| NodeF32 {
                feature: out.feature[i],
                threshold: out.thresh_f32[i],
                left: out.left[i],
                right: out.right[i],
            })
            .collect();
        out.nodes_ord = (0..out.feature.len())
            .map(|i| NodeOrd {
                feature: out.feature[i],
                threshold: out.thresh_ord[i],
                left: out.left[i],
                right: out.right[i],
            })
            .collect();
        out
    }

    /// Total node count.
    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// Permute every tree's SoA columns into BFS level order.
    ///
    /// Branch child indices are remapped through the permutation; leaf
    /// payload indices (`left` of a LEAF node) address the leaf arrays,
    /// not nodes, and are carried over untouched — so traversal reaches
    /// bit-identical leaf payloads in either order. The root keeps local
    /// index 0 (BFS starts there), which `walk_*` relies on.
    fn reorder_breadth_first(&mut self) {
        for t in 0..self.n_trees {
            let lo = self.tree_offsets[t] as usize;
            let hi = self.tree_offsets[t + 1] as usize;
            let n = hi - lo;
            if n <= 1 {
                continue;
            }
            // order[new] = old (tree-local indices).
            let mut order: Vec<u32> = Vec::with_capacity(n);
            let mut seen = vec![false; n];
            let mut queue: VecDeque<u32> = VecDeque::with_capacity(n);
            queue.push_back(0);
            seen[0] = true;
            while let Some(old) = queue.pop_front() {
                order.push(old);
                let i = lo + old as usize;
                if self.feature[i] != LEAF {
                    for child in [self.left[i], self.right[i]] {
                        if !seen[child as usize] {
                            seen[child as usize] = true;
                            queue.push_back(child);
                        }
                    }
                }
            }
            // Defensive: a validated model has no unreachable nodes, but
            // keep any that exist (in original relative order) so the
            // permutation stays total.
            for (old, s) in seen.iter().enumerate() {
                if !s {
                    order.push(old as u32);
                }
            }
            let mut new_of = vec![0u32; n];
            for (new, &old) in order.iter().enumerate() {
                new_of[old as usize] = new as u32;
            }
            let mut feature = Vec::with_capacity(n);
            let mut thresh_f32 = Vec::with_capacity(n);
            let mut thresh_ord = Vec::with_capacity(n);
            let mut left = Vec::with_capacity(n);
            let mut right = Vec::with_capacity(n);
            for &old in &order {
                let i = lo + old as usize;
                feature.push(self.feature[i]);
                thresh_f32.push(self.thresh_f32[i]);
                thresh_ord.push(self.thresh_ord[i]);
                if self.feature[i] == LEAF {
                    left.push(self.left[i]);
                    right.push(self.right[i]);
                } else {
                    left.push(new_of[self.left[i] as usize]);
                    right.push(new_of[self.right[i] as usize]);
                }
            }
            self.feature[lo..hi].copy_from_slice(&feature);
            self.thresh_f32[lo..hi].copy_from_slice(&thresh_f32);
            self.thresh_ord[lo..hi].copy_from_slice(&thresh_ord);
            self.left[lo..hi].copy_from_slice(&left);
            self.right[lo..hi].copy_from_slice(&right);
        }
    }

    /// Walk tree `t` on a raw float row, returning the leaf payload index.
    ///
    /// SAFETY of the unchecked indexing: `Model::validate()` (enforced at
    /// compile time) guarantees child indices stay inside the tree and
    /// feature indices stay below `n_features`; callers pass rows of at
    /// least `n_features` values (asserted here once, not per node).
    #[inline]
    pub fn walk_f32(&self, t: usize, row: &[f32]) -> u32 {
        assert!(row.len() >= self.n_features);
        let base = self.tree_offsets[t] as usize;
        let nodes = &self.nodes_f32;
        let mut i = base;
        loop {
            let n = unsafe { nodes.get_unchecked(i) };
            if n.feature == LEAF {
                return n.left;
            }
            let go_left = unsafe { *row.get_unchecked(n.feature as usize) } <= n.threshold;
            i = base + if go_left { n.left } else { n.right } as usize;
        }
    }

    /// Walk tree `t` on an ordered-u32 transformed row (same safety
    /// argument as [`Self::walk_f32`]).
    #[inline]
    pub fn walk_ord(&self, t: usize, row_ord: &[u32]) -> u32 {
        assert!(row_ord.len() >= self.n_features);
        let base = self.tree_offsets[t] as usize;
        let nodes = &self.nodes_ord;
        let mut i = base;
        loop {
            let n = unsafe { nodes.get_unchecked(i) };
            if n.feature == LEAF {
                return n.left;
            }
            let go_left = unsafe { *row_ord.get_unchecked(n.feature as usize) } <= n.threshold;
            i = base + if go_left { n.left } else { n.right } as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn model() -> Model {
        let ds = shuttle_like(1500, 1);
        RandomForest::train(&ds, &ForestParams { n_trees: 6, max_depth: 5, ..Default::default() }, 3)
    }

    #[test]
    fn compile_shapes() {
        let m = model();
        let c = CompiledForest::compile(&m);
        assert_eq!(c.n_trees, 6);
        assert_eq!(c.tree_offsets.len(), 7);
        assert_eq!(c.n_nodes(), m.n_nodes());
        assert_eq!(c.leaf_f32.len(), m.n_leaves() * m.n_classes);
        assert_eq!(c.leaf_u32.len(), c.leaf_f32.len());
        assert_eq!(c.feature.len(), c.thresh_f32.len());
        assert_eq!(c.feature.len(), c.left.len());
    }

    #[test]
    fn walks_agree_with_ir_eval() {
        let m = model();
        let c = CompiledForest::compile(&m);
        let ds = shuttle_like(200, 2);
        for i in 0..ds.n_rows() {
            let row = ds.row(i);
            let row_ord: Vec<u32> = row.iter().map(|&x| ordered_u32(x)).collect();
            for t in 0..c.n_trees {
                let leaf_ir = m.trees[t].evaluate(row);
                let pf = c.walk_f32(t, row) as usize;
                let po = c.walk_ord(t, &row_ord) as usize;
                assert_eq!(pf, po, "float and flint walks disagree");
                let got = &c.leaf_f32[pf * c.n_classes..(pf + 1) * c.n_classes];
                assert_eq!(got, leaf_ir);
            }
        }
    }

    #[test]
    fn breadth_order_reaches_identical_leaves() {
        let m = model();
        let depth = CompiledForest::compile_with(&m, NodeOrder::Depth);
        let breadth = CompiledForest::compile_with(&m, NodeOrder::Breadth);
        assert_eq!(depth.order, NodeOrder::Depth);
        assert_eq!(breadth.order, NodeOrder::Breadth);
        assert_eq!(depth.n_nodes(), breadth.n_nodes());
        // Same leaf arrays (payloads are not permuted)...
        assert_eq!(depth.leaf_f32, breadth.leaf_f32);
        assert_eq!(depth.leaf_u32, breadth.leaf_u32);
        // ...but a genuinely different node ordering somewhere.
        assert_ne!(
            (&depth.feature, &depth.left),
            (&breadth.feature, &breadth.left),
            "reorder was a no-op on a depth-6 forest"
        );
        let ds = shuttle_like(300, 5);
        for i in 0..ds.n_rows() {
            let row = ds.row(i);
            let row_ord: Vec<u32> = row.iter().map(|&x| ordered_u32(x)).collect();
            for t in 0..depth.n_trees {
                assert_eq!(depth.walk_f32(t, row), breadth.walk_f32(t, row));
                assert_eq!(depth.walk_ord(t, &row_ord), breadth.walk_ord(t, &row_ord));
            }
        }
    }

    #[test]
    fn breadth_order_packs_roots_first() {
        // In BFS order, node 1 of any multi-node tree is a child of the
        // root (depth order would put the root's left subtree there, so
        // node 1 is the same — but node 2 differs for depth>1 trees:
        // BFS puts the root's *right* child at 2).
        let m = model();
        let b = CompiledForest::compile_with(&m, NodeOrder::Breadth);
        for t in 0..b.n_trees {
            let lo = b.tree_offsets[t] as usize;
            if b.feature[lo] == LEAF {
                continue; // single-node tree
            }
            assert_eq!(b.left[lo], 1, "tree {t}: root's left child is BFS slot 1");
            assert_eq!(b.right[lo], 2, "tree {t}: root's right child is BFS slot 2");
        }
    }

    #[test]
    #[should_panic(expected = "RF model")]
    fn rejects_gbt() {
        let mut m = model();
        m.kind = ModelKind::Gbt;
        CompiledForest::compile(&m);
    }
}
