//! Explicit SIMD execution backends for the batch kernels.
//!
//! The predicated fixed-trip walk ([`super::batch`]) and the QuickScorer
//! condition-stream scan ([`super::quickscorer`]) were shaped so LLVM
//! *can* autovectorize them — but autovectorization is a hope, not a
//! contract. This module makes the lane parallelism explicit: hand
//! written intrinsic inner loops behind a runtime-dispatched
//! [`SimdBackend`], so a binary built for generic `x86_64` / `aarch64`
//! still runs the vector path on capable hardware and falls back to the
//! scalar kernels everywhere else.
//!
//! ## Backends
//!
//! * [`SimdBackend::Scalar`] — the existing scalar kernels (always
//!   available; the reference semantics).
//! * [`SimdBackend::Avx2`] — x86_64 with AVX2 detected at runtime
//!   (`is_x86_feature_detected!("avx2")`). Eight u32 lane cursors live
//!   in one `__m256i`; node words come from two `vpgatherdd` gathers
//!   over the [`CompiledForest`](super::CompiledForest) SoA mirror
//!   planes, and the descent is pure mask arithmetic.
//! * [`SimdBackend::Neon`] — aarch64 NEON (baseline on AArch64, still
//!   verified via `is_aarch64_feature_detected!`). NEON has no gather,
//!   so node/row fetches stay scalar while the compare + mask + add
//!   descent runs on `uint32x4_t` half-tiles.
//!
//! ## Selection
//!
//! [`SimdBackend::resolve`] picks the best *detected* backend, unless
//! the [`BACKEND_ENV`] environment variable (CLI: `--backend`) forces
//! one. A forced backend that the host cannot execute is refused loudly
//! and falls back to the best available one — the `#[target_feature]`
//! blocks below must stay unreachable unless the corresponding CPU
//! feature was actually detected (executing AVX2 code on a non-AVX2
//! core is undefined behavior, not a slow path).
//!
//! ## Parity (load-bearing — the parity suite sweeps this dimension)
//!
//! Every backend routes every lane through the literal `!(x <= t)`
//! comparison sequence of the scalar walkers (`x > t` unsigned in the
//! ordered-u32 domain via the sign-bias trick; `_CMP_NLE_UQ` /
//! `vmvnq_u32(vcleq_f32(..))` in the f32 domain, preserving NaN
//! routing), and leaf payloads are accumulated in ascending tree order
//! by the shared drivers — so Scalar, AVX2 and NEON results are
//! **byte-identical**. The backend is a pure performance knob, exactly
//! like [`super::TraversalKernel`].

use std::sync::OnceLock;

/// Environment variable forcing an execution backend (`scalar`, `avx2`,
/// `neon`); the CLI `--backend` flag sets it process-wide. Invalid or
/// unavailable values are refused loudly and fall back to the best
/// detected backend.
pub const BACKEND_ENV: &str = "INTREEGER_BACKEND";

/// Which SIMD execution backend the batch kernels use behind
/// [`super::TraversalKernel::Branchless`] and
/// [`super::TraversalKernel::QuickScorer`] (the branchy early-exit walk
/// is inherently divergent and always runs scalar).
///
/// All backends produce bit-identical results (module docs); this is a
/// pure performance knob, swept by the serving coordinator's startup
/// auto-calibration alongside the traversal kernel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SimdBackend {
    /// Portable scalar kernels (always available; reference semantics).
    #[default]
    Scalar,
    /// x86_64 AVX2 intrinsics (8-lane gathers + mask-arithmetic descent).
    Avx2,
    /// aarch64 NEON intrinsics (4-lane half-tiles, scalar gathers).
    Neon,
}

impl SimdBackend {
    /// Display / calibration-log / env name of the backend.
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Avx2 => "avx2",
            SimdBackend::Neon => "neon",
        }
    }

    /// Parse a backend name (inverse of [`Self::name`]).
    pub fn from_name(name: &str) -> Option<SimdBackend> {
        Self::all().into_iter().find(|b| b.name() == name)
    }

    /// Every backend the enum knows, available on this host or not
    /// (CLI enumerations use this; execution sweeps use
    /// [`Self::available`]).
    pub fn all() -> [SimdBackend; 3] {
        [SimdBackend::Scalar, SimdBackend::Avx2, SimdBackend::Neon]
    }

    /// Whether this backend can execute on the current host (CPU
    /// feature detected *and* the matching architecture compiled in).
    pub fn is_available(self) -> bool {
        Self::available().contains(&self)
    }

    /// The backends executable on this host, scalar first, best last.
    /// Detection runs once and is cached.
    pub fn available() -> &'static [SimdBackend] {
        static AVAILABLE: OnceLock<Vec<SimdBackend>> = OnceLock::new();
        AVAILABLE.get_or_init(detect)
    }

    /// The fastest-expected available backend (the last of
    /// [`Self::available`]): AVX2 / NEON when detected, scalar otherwise.
    pub fn best() -> SimdBackend {
        *Self::available().last().expect("scalar backend is always available")
    }

    /// Resolve the backend to use: the [`BACKEND_ENV`] override when set
    /// (validated against [`Self::available`]; refused loudly when the
    /// host cannot execute it), otherwise [`Self::best`]. Engines use
    /// this as their compile-time default, so the override pins every
    /// engine in the process.
    pub fn resolve() -> SimdBackend {
        match std::env::var(BACKEND_ENV) {
            Ok(raw) => match Self::from_name(raw.trim()) {
                Some(b) if b.is_available() => b,
                Some(b) => {
                    eprintln!(
                        "intreeger: {BACKEND_ENV}={} is not executable on this host \
                         (available: {:?}); using {}",
                        b.name(),
                        Self::available().iter().map(|b| b.name()).collect::<Vec<_>>(),
                        Self::best().name()
                    );
                    Self::best()
                }
                None => {
                    eprintln!(
                        "intreeger: unknown {BACKEND_ENV}='{raw}' (use scalar | avx2 | neon); \
                         using {}",
                        Self::best().name()
                    );
                    Self::best()
                }
            },
            Err(_) => Self::best(),
        }
    }

    /// The backends a calibration sweep should time: just the forced one
    /// when [`BACKEND_ENV`] is set (the override pins the choice),
    /// otherwise everything available.
    pub fn sweep() -> Vec<SimdBackend> {
        if std::env::var(BACKEND_ENV).is_ok() {
            vec![Self::resolve()]
        } else {
            Self::available().to_vec()
        }
    }

    /// Human-readable CPU SIMD features detected on this host (reported
    /// by `inspect`, the serving metrics snapshot, and the bench JSON).
    pub fn detected_features() -> Vec<&'static str> {
        let mut feats = Vec::new();
        #[cfg(target_arch = "x86_64")]
        {
            feats.push("sse2"); // x86_64 baseline
            if is_x86_feature_detected!("avx2") {
                feats.push("avx2");
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                feats.push("neon");
            }
        }
        feats
    }
}

/// Runtime backend detection (cached by [`SimdBackend::available`]).
fn detect() -> Vec<SimdBackend> {
    let mut v = vec![SimdBackend::Scalar];
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        v.push(SimdBackend::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        v.push(SimdBackend::Neon);
    }
    v
}

// ---------------------------------------------------------------------------
// AVX2 kernels (x86_64).
//
// Layout recap (see `compiled.rs`): the SoA mirror keeps two u32 planes
// per node — `tw` (threshold word or leaf payload) and `ffl`
// (`ff | left << 16`, i.e. feature-and-leaf-bit in the low half,
// left-child / self-loop index in the high half). For node `i`:
//   feature      = ffl & 0x7FFF
//   leaf bit     = (ffl >> 15) & 1          (branch_mask = leaf_bit ^ 1)
//   left / self  = ffl >> 16
// and the predicated descent is idx = left + (go_right & branch_mask),
// identical to the scalar `walk_tile_lockstep` step.

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::super::batch::{PackedTrees, TILE_ROWS};
    use std::arch::x86_64::*;

    /// AVX2 predicated fixed-trip walk of one tree over one tile: eight
    /// u32 lane cursors in one `__m256i`, node fetches via two
    /// `vpgatherdd` gathers over the SoA mirror planes, descent by mask
    /// arithmetic. `row_base[r]` is the element offset of lane `r`'s row
    /// (ragged tails pass clamped offsets that duplicate the last real
    /// lane — exactly the scalar tail walker's trick).
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 via [`super::SimdBackend`]
    /// detection. Memory safety of the gathers relies on the compiled
    /// invariants the scalar walkers also rely on (`Model::validate()`
    /// bounds child/feature indices; leaves self-loop and read feature
    /// 0) plus the driver-checked bounds: every `row_base[r] + feature`
    /// stays inside `rows` (the drivers assert the batch shape and that
    /// `rows.len() <= i32::MAX`, so the i32 gather indices cannot wrap).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn walk_tile_ord(
        trees: &PackedTrees,
        t: usize,
        rows: &[u32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        let base = trees.tree_offsets[t] as usize;
        let depth = trees.tree_depths[t];
        let tw = trees.tw_plane.as_ptr().add(base) as *const i32;
        let ffl = trees.ffl_plane.as_ptr().add(base) as *const i32;
        let rowp = rows.as_ptr() as *const i32;
        let vrow_base = _mm256_loadu_si256(row_base.as_ptr() as *const __m256i);
        let bias = _mm256_set1_epi32(i32::MIN);
        let feat_mask = _mm256_set1_epi32(0x7FFF);
        let one = _mm256_set1_epi32(1);
        let mut idx = _mm256_setzero_si256();
        for _ in 0..depth {
            let vtw = _mm256_i32gather_epi32::<4>(tw, idx);
            let vffl = _mm256_i32gather_epi32::<4>(ffl, idx);
            let feat = _mm256_and_si256(vffl, feat_mask);
            let left = _mm256_srli_epi32::<16>(vffl);
            // branch_mask = ((ffl >> 15) & 1) ^ 1 — 0 for leaves.
            let bm = _mm256_xor_si256(_mm256_and_si256(_mm256_srli_epi32::<15>(vffl), one), one);
            let x = _mm256_i32gather_epi32::<4>(rowp, _mm256_add_epi32(vrow_base, feat));
            // Unsigned x > tw via the sign-bias trick (AVX2 has only the
            // signed 32-bit compare) — same predicate as the scalar walk.
            let gt = _mm256_cmpgt_epi32(_mm256_xor_si256(x, bias), _mm256_xor_si256(vtw, bias));
            idx = _mm256_add_epi32(left, _mm256_and_si256(gt, bm));
        }
        // Every lane is parked on its leaf; the payload rides in tw.
        let payload = _mm256_i32gather_epi32::<4>(tw, idx);
        _mm256_storeu_si256(leaves.as_mut_ptr() as *mut __m256i, payload);
    }

    /// AVX2 walk in the raw-f32 threshold domain. The descent predicate
    /// is `_CMP_NLE_UQ` — the literal IEEE negation of `x <= t`
    /// (unordered → true), so NaN routes right exactly like the scalar
    /// `!(x <= t)` and the generated C.
    ///
    /// # Safety
    ///
    /// Same contract as [`walk_tile_ord`].
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn walk_tile_f32(
        trees: &PackedTrees,
        t: usize,
        rows: &[f32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        let base = trees.tree_offsets[t] as usize;
        let depth = trees.tree_depths[t];
        let tw = trees.tw_plane.as_ptr().add(base) as *const i32;
        let ffl = trees.ffl_plane.as_ptr().add(base) as *const i32;
        let vrow_base = _mm256_loadu_si256(row_base.as_ptr() as *const __m256i);
        let feat_mask = _mm256_set1_epi32(0x7FFF);
        let one = _mm256_set1_epi32(1);
        let mut idx = _mm256_setzero_si256();
        for _ in 0..depth {
            let vtw = _mm256_i32gather_epi32::<4>(tw, idx);
            let vffl = _mm256_i32gather_epi32::<4>(ffl, idx);
            let feat = _mm256_and_si256(vffl, feat_mask);
            let left = _mm256_srli_epi32::<16>(vffl);
            let bm = _mm256_xor_si256(_mm256_and_si256(_mm256_srli_epi32::<15>(vffl), one), one);
            let x = _mm256_i32gather_ps::<4>(rows.as_ptr(), _mm256_add_epi32(vrow_base, feat));
            let gr = _mm256_cmp_ps::<_CMP_NLE_UQ>(x, _mm256_castsi256_ps(vtw));
            idx = _mm256_add_epi32(left, _mm256_and_si256(_mm256_castps_si256(gr), bm));
        }
        let payload = _mm256_i32gather_epi32::<4>(tw, idx);
        _mm256_storeu_si256(leaves.as_mut_ptr() as *mut __m256i, payload);
    }

    /// Length of the leading `x > words[i]` run of an ascending
    /// QuickScorer condition stream (ordered-u32 domain), eight
    /// conditions per compare. The stream is threshold-sorted, so the
    /// "go right" conditions are a prefix; the driver ANDs exactly that
    /// many false-leaf masks — the same masks, in the same order, as the
    /// scalar early-exit scan.
    ///
    /// # Safety
    ///
    /// Caller must have verified AVX2 availability; `words` is an
    /// ordinary slice and all loads stay within it.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn qs_false_prefix_ord(x: u32, words: &[u32]) -> usize {
        let bias = _mm256_set1_epi32(i32::MIN);
        let vx = _mm256_xor_si256(_mm256_set1_epi32(x as i32), bias);
        let mut p = 0usize;
        while p + 8 <= words.len() {
            let vt = _mm256_loadu_si256(words.as_ptr().add(p) as *const __m256i);
            let gt = _mm256_cmpgt_epi32(vx, _mm256_xor_si256(vt, bias));
            // 8-bit mask, bit r set when lane r is still "go right".
            let m = _mm256_movemask_ps(_mm256_castsi256_ps(gt)) as u32;
            let run = (!m).trailing_zeros() as usize; // leading ones of m
            p += run;
            if run < 8 {
                return p;
            }
        }
        while p < words.len() && x > words[p] {
            p += 1;
        }
        p
    }

    /// f32-domain variant of [`qs_false_prefix_ord`]: the compare is
    /// `_CMP_NLE_UQ` — the literal `!(x <= t)` of the scalar scan.
    ///
    /// # Safety
    ///
    /// Same contract as [`qs_false_prefix_ord`].
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn qs_false_prefix_f32(x: f32, words: &[u32]) -> usize {
        let vx = _mm256_set1_ps(x);
        let mut p = 0usize;
        while p + 8 <= words.len() {
            let vt =
                _mm256_castsi256_ps(_mm256_loadu_si256(words.as_ptr().add(p) as *const __m256i));
            let gr = _mm256_cmp_ps::<_CMP_NLE_UQ>(vx, vt);
            let m = _mm256_movemask_ps(gr) as u32;
            let run = (!m).trailing_zeros() as usize;
            p += run;
            if run < 8 {
                return p;
            }
        }
        while p < words.len() && !(x <= f32::from_bits(words[p])) {
            p += 1;
        }
        p
    }
}

// ---------------------------------------------------------------------------
// NEON kernels (aarch64). NEON has no gather instruction, so node and
// row fetches stay scalar (lane-by-lane into a stack array) while the
// compare + branch-mask + add descent runs on uint32x4_t half-tiles.
// The comparisons are exactly the scalar walkers': vcgtq_u32 is the
// native unsigned >, and vmvnq_u32(vcleq_f32(x, t)) is the literal
// !(x <= t) including NaN routing.

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::super::batch::{PackedTrees, TILE_ROWS};
    use std::arch::aarch64::*;

    /// NEON predicated fixed-trip walk (ordered-u32 domain): two
    /// `uint32x4_t` half-tiles of lane cursors; scalar gathers, vector
    /// descent. `row_base` follows the same clamped-duplicate tail
    /// convention as the AVX2 walker.
    ///
    /// # Safety
    ///
    /// Caller must have verified NEON via [`super::SimdBackend`]
    /// detection; memory safety follows the scalar walkers' argument
    /// (validated child/feature indices, driver-checked batch shape).
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn walk_tile_ord(
        trees: &PackedTrees,
        t: usize,
        rows: &[u32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        let base = trees.tree_offsets[t] as usize;
        let depth = trees.tree_depths[t];
        let tw = trees.tw_plane.as_ptr().add(base);
        let ffl = trees.ffl_plane.as_ptr().add(base);
        let rp = rows.as_ptr();
        let one = vdupq_n_u32(1);
        for half in 0..2 {
            let rb = &row_base[half * 4..half * 4 + 4];
            let mut idx = vdupq_n_u32(0);
            for _ in 0..depth {
                let mut ia = [0u32; 4];
                vst1q_u32(ia.as_mut_ptr(), idx);
                let mut tww = [0u32; 4];
                let mut fflw = [0u32; 4];
                let mut xs = [0u32; 4];
                for (l, &i) in ia.iter().enumerate() {
                    tww[l] = *tw.add(i as usize);
                    fflw[l] = *ffl.add(i as usize);
                    xs[l] = *rp.add(rb[l] as usize + (fflw[l] & 0x7FFF) as usize);
                }
                let vtw = vld1q_u32(tww.as_ptr());
                let vffl = vld1q_u32(fflw.as_ptr());
                let vx = vld1q_u32(xs.as_ptr());
                let left = vshrq_n_u32::<16>(vffl);
                let bm = veorq_u32(vandq_u32(vshrq_n_u32::<15>(vffl), one), one);
                let gt = vcgtq_u32(vx, vtw);
                idx = vaddq_u32(left, vandq_u32(gt, bm));
            }
            let mut ia = [0u32; 4];
            vst1q_u32(ia.as_mut_ptr(), idx);
            for (l, &i) in ia.iter().enumerate() {
                leaves[half * 4 + l] = *tw.add(i as usize);
            }
        }
    }

    /// NEON walk in the raw-f32 domain (`vmvnq_u32(vcleq_f32(..))` is
    /// the literal `!(x <= t)`, NaN → go right, like the scalar walk).
    ///
    /// # Safety
    ///
    /// Same contract as [`walk_tile_ord`].
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn walk_tile_f32(
        trees: &PackedTrees,
        t: usize,
        rows: &[f32],
        row_base: &[u32; TILE_ROWS],
        leaves: &mut [u32; TILE_ROWS],
    ) {
        let base = trees.tree_offsets[t] as usize;
        let depth = trees.tree_depths[t];
        let tw = trees.tw_plane.as_ptr().add(base);
        let ffl = trees.ffl_plane.as_ptr().add(base);
        let rp = rows.as_ptr();
        let one = vdupq_n_u32(1);
        for half in 0..2 {
            let rb = &row_base[half * 4..half * 4 + 4];
            let mut idx = vdupq_n_u32(0);
            for _ in 0..depth {
                let mut ia = [0u32; 4];
                vst1q_u32(ia.as_mut_ptr(), idx);
                let mut tww = [0u32; 4];
                let mut fflw = [0u32; 4];
                let mut xs = [0f32; 4];
                for (l, &i) in ia.iter().enumerate() {
                    tww[l] = *tw.add(i as usize);
                    fflw[l] = *ffl.add(i as usize);
                    xs[l] = *rp.add(rb[l] as usize + (fflw[l] & 0x7FFF) as usize);
                }
                let vtw = vld1q_u32(tww.as_ptr());
                let vffl = vld1q_u32(fflw.as_ptr());
                let vx = vld1q_f32(xs.as_ptr());
                let left = vshrq_n_u32::<16>(vffl);
                let bm = veorq_u32(vandq_u32(vshrq_n_u32::<15>(vffl), one), one);
                let gr = vmvnq_u32(vcleq_f32(vx, vreinterpretq_f32_u32(vtw)));
                idx = vaddq_u32(left, vandq_u32(gr, bm));
            }
            let mut ia = [0u32; 4];
            vst1q_u32(ia.as_mut_ptr(), idx);
            for (l, &i) in ia.iter().enumerate() {
                leaves[half * 4 + l] = *tw.add(i as usize);
            }
        }
    }

    /// NEON QuickScorer false-prefix scan (ordered-u32 domain), four
    /// conditions per compare; lane masks are packed via `vmovn_u32`
    /// into one u64 (16 bits per lane) for the leading-run count.
    ///
    /// # Safety
    ///
    /// Caller must have verified NEON availability.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn qs_false_prefix_ord(x: u32, words: &[u32]) -> usize {
        let vx = vdupq_n_u32(x);
        let mut p = 0usize;
        while p + 4 <= words.len() {
            let vt = vld1q_u32(words.as_ptr().add(p));
            let gt = vcgtq_u32(vx, vt);
            let packed = vget_lane_u64::<0>(vreinterpret_u64_u16(vmovn_u32(gt)));
            let run = ((!packed).trailing_zeros() / 16) as usize;
            p += run;
            if run < 4 {
                return p;
            }
        }
        while p < words.len() && x > words[p] {
            p += 1;
        }
        p
    }

    /// f32-domain variant of [`qs_false_prefix_ord`] (`!(x <= t)`).
    ///
    /// # Safety
    ///
    /// Same contract as [`qs_false_prefix_ord`].
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn qs_false_prefix_f32(x: f32, words: &[u32]) -> usize {
        let vx = vdupq_n_f32(x);
        let mut p = 0usize;
        while p + 4 <= words.len() {
            let vt = vreinterpretq_f32_u32(vld1q_u32(words.as_ptr().add(p)));
            let gr = vmvnq_u32(vcleq_f32(vx, vt));
            let packed = vget_lane_u64::<0>(vreinterpret_u64_u16(vmovn_u32(gr)));
            let run = ((!packed).trailing_zeros() / 16) as usize;
            p += run;
            if run < 4 {
                return p;
            }
        }
        while p < words.len() && !(x <= f32::from_bits(words[p])) {
            p += 1;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_parse_roundtrip() {
        assert_eq!(SimdBackend::all().len(), 3);
        for b in SimdBackend::all() {
            assert_eq!(SimdBackend::from_name(b.name()), Some(b));
        }
        assert_eq!(SimdBackend::from_name("avx512"), None);
        assert_eq!(SimdBackend::default(), SimdBackend::Scalar);
    }

    #[test]
    fn scalar_always_available_and_first() {
        let avail = SimdBackend::available();
        assert_eq!(avail[0], SimdBackend::Scalar);
        assert!(SimdBackend::Scalar.is_available());
        assert!(SimdBackend::best().is_available());
        // Architecture sanity: a backend can only be available on its
        // own architecture.
        #[cfg(not(target_arch = "x86_64"))]
        assert!(!SimdBackend::Avx2.is_available());
        #[cfg(not(target_arch = "aarch64"))]
        assert!(!SimdBackend::Neon.is_available());
    }

    #[test]
    fn detected_features_match_availability() {
        let feats = SimdBackend::detected_features();
        assert_eq!(SimdBackend::Avx2.is_available(), feats.contains(&"avx2"));
        assert_eq!(SimdBackend::Neon.is_available(), feats.contains(&"neon"));
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_qs_prefix_matches_scalar_scan() {
        if !SimdBackend::Avx2.is_available() {
            eprintln!("avx2 not available; skipping");
            return;
        }
        let mut rng = crate::util::Rng::new(0x51D);
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100] {
            // Ascending stream like a real condition bucket.
            let mut words: Vec<u32> = (0..len).map(|_| rng.next_u64() as u32 % 50_000).collect();
            words.sort_unstable();
            for probe in 0..40u32 {
                let x = probe * 1_500;
                let want = words.iter().take_while(|&&w| x > w).count();
                // SAFETY: AVX2 availability checked above.
                let got = unsafe { avx2::qs_false_prefix_ord(x, &words) };
                assert_eq!(got, want, "len={len} x={x}");
                let xf = x as f32 * 0.25 - 6_000.0;
                let wantf =
                    words.iter().take_while(|&&w| !(xf <= f32::from_bits(w))).count();
                // SAFETY: AVX2 availability checked above.
                let gotf = unsafe { avx2::qs_false_prefix_f32(xf, &words) };
                assert_eq!(gotf, wantf, "f32 len={len} x={xf}");
            }
        }
    }
}
