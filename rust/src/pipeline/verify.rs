//! Float-vs-integer parity harness — the pipeline stage that turns the
//! paper's "without loss of precision" claim into a machine-checked
//! verdict.
//!
//! For a Random Forest the holdout set is pushed through the f32
//! reference engine and both integer engines (FlInt and InTreeger),
//! per-row **and** batched under every [`TraversalKernel`] × available
//! [`SimdBackend`], and the predictions must be argmax-identical
//! everywhere. On top of the class
//! identity, the fixed-point accumulators are compared per class against
//! an exact `f64` re-accumulation of the leaf probabilities: the paper's
//! §III-A analysis bounds the absolute error by `n/2^32`, and the
//! verdict records the measured maximum against that bound (plus the
//! clamp slack documented in [`crate::quant::prob_to_fixed`]).
//!
//! For a GBT the reference is the float softmax model; the integer
//! engine ([`crate::inference::GbtIntEngine`]) must match its argmax on
//! every row and kernel, and reported probabilities must stay within
//! the margin-grid error `(T+1)/2^(shift+1)` — `T` is the model's
//! *total* tree count (every tree's per-class vector plus the base
//! score is accumulated, each rounding within half a grid step) — plus
//! a float-softmax reporting slack (probability *reporting* is the one
//! place floats appear).

use crate::data::Dataset;
use crate::inference::{
    compile_variant, Engine, FlIntEngine, FloatEngine, GbtIntEngine, IntEngine, SimdBackend,
    TraversalKernel, Variant,
};
use crate::ir::{Model, ModelKind};
use crate::quant::{self, TWO_32};

/// Machine-checked outcome of the float-vs-integer parity stage.
#[derive(Clone, Debug)]
pub struct ParityVerdict {
    /// Holdout rows checked.
    pub rows: usize,
    /// Total argmax disagreements against the float reference, summed
    /// over every engine × kernel × (per-row, batched) combination.
    pub mismatches: usize,
    /// The paper's headline claim: no prediction changed anywhere.
    pub argmax_identical: bool,
    /// Traversal kernels swept (every one must agree bit-for-bit).
    pub kernels: Vec<String>,
    /// Engines compared against the float reference.
    pub engines: Vec<String>,
    /// Per-class maximum absolute probability error of the fixed-point
    /// representation against an exact f64 re-accumulation.
    pub per_class_max_error: Vec<f64>,
    /// Maximum of [`Self::per_class_max_error`].
    pub max_abs_error: f64,
    /// The bound the measured error is checked against (`n/2^32` plus
    /// clamp slack for RF; margin-grid + softmax-reporting slack for GBT).
    pub error_bound: f64,
    /// `max_abs_error <= error_bound`.
    pub within_bound: bool,
    /// Holdout accuracy of the float reference.
    pub accuracy_float: f64,
    /// Holdout accuracy of the integer-only engine.
    pub accuracy_int: f64,
}

impl ParityVerdict {
    /// Overall verdict: argmax-identical *and* error within the bound.
    pub fn passed(&self) -> bool {
        self.argmax_identical && self.within_bound
    }
}

/// Verify a Random Forest on a holdout set.
///
/// Sweeps all three engine variants and all three traversal kernels;
/// see the module docs for what is checked. The holdout must be
/// non-empty and match the model's feature count.
///
/// ```
/// use intreeger::pipeline::verify::verify_rf;
/// use intreeger::trees::{ForestParams, RandomForest};
/// let ds = intreeger::data::shuttle_like(300, 3);
/// let model = RandomForest::train(
///     &ds,
///     &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() },
///     3,
/// );
/// let v = verify_rf(&model, &ds);
/// assert!(v.passed(), "paper claim violated: {v:?}");
/// assert_eq!(v.mismatches, 0);
/// ```
pub fn verify_rf(model: &Model, holdout: &Dataset) -> ParityVerdict {
    assert_eq!(model.kind, ModelKind::RandomForest, "verify_rf needs an RF model");
    assert!(holdout.n_rows() > 0, "empty holdout set");
    assert_eq!(holdout.n_features, model.n_features, "holdout feature count mismatch");
    let n_trees = model.trees.len();
    let fe = FloatEngine::compile(model);
    let fl = FlIntEngine::compile(model);
    let ie = IntEngine::compile(model);

    let mut mismatches = 0usize;
    let mut correct_float = 0usize;
    let mut correct_int = 0usize;
    let mut per_class = vec![0.0f64; model.n_classes];
    let mut float_preds: Vec<u32> = Vec::with_capacity(holdout.n_rows());
    let mut ref64 = vec![0.0f64; model.n_classes];

    for i in 0..holdout.n_rows() {
        let row = holdout.row(i);
        let a = fe.predict(row);
        let b = fl.predict(row);
        let c = ie.predict(row);
        mismatches += usize::from(a != b) + usize::from(a != c);
        correct_float += usize::from(a == holdout.labels[i]);
        correct_int += usize::from(c == holdout.labels[i]);
        float_preds.push(a);

        // Exact f64 reference: the mean of the f32 leaf probabilities,
        // accumulated without float32 rounding. The fixed-point estimate
        // must sit within n/2^32 of this (paper §III-A).
        ref64.iter_mut().for_each(|v| *v = 0.0);
        for tree in &model.trees {
            for (k, &v) in tree.evaluate(row).iter().enumerate() {
                ref64[k] += v as f64;
            }
        }
        let fixed = ie.predict_fixed(row);
        for k in 0..model.n_classes {
            let err = (fixed[k] as f64 / TWO_32 - ref64[k] / n_trees as f64).abs();
            if err > per_class[k] {
                per_class[k] = err;
            }
        }
    }

    // Batched sweep: every variant × kernel × available SIMD backend
    // must reproduce the scalar float predictions element-wise. Compile
    // each variant once — switching the kernel/backend is a cheap knob
    // on a compiled engine.
    let kernels: Vec<String> =
        TraversalKernel::all().iter().map(|k| k.name().to_string()).collect();
    for v in Variant::all() {
        let mut e = compile_variant(model, v);
        for kernel in TraversalKernel::all() {
            e.set_kernel(kernel);
            for &backend in SimdBackend::available() {
                e.set_backend(backend);
                let preds = e.predict_batch(&holdout.features);
                mismatches += preds.iter().zip(&float_preds).filter(|(p, f)| p != f).count();
            }
        }
    }

    let max_abs_error = per_class.iter().cloned().fold(0.0f64, f64::max);
    // n/2^32 plus 2 ULP of the fixed-point grid for the overflow clamp
    // (see quant::prob_to_fixed: clamped leaves move by at most one grid
    // step, and the comparison itself floors once more).
    let error_bound = quant::error_bound(n_trees) + 2.0 / TWO_32;
    ParityVerdict {
        rows: holdout.n_rows(),
        mismatches,
        argmax_identical: mismatches == 0,
        kernels,
        engines: Variant::all().iter().map(|v| v.name().to_string()).collect(),
        max_abs_error,
        per_class_max_error: per_class,
        error_bound,
        within_bound: max_abs_error <= error_bound,
        accuracy_float: correct_float as f64 / holdout.n_rows() as f64,
        accuracy_int: correct_int as f64 / holdout.n_rows() as f64,
    }
}

/// Verify a gradient-boosted model on a holdout set: the integer margin
/// engine must match the float model's argmax on every row (per-row and
/// batched under every kernel), and reported probabilities must stay
/// within the margin-quantization bound plus float-softmax slack.
pub fn verify_gbt(model: &Model, holdout: &Dataset) -> ParityVerdict {
    assert_eq!(model.kind, ModelKind::Gbt, "verify_gbt needs a GBT model");
    assert!(holdout.n_rows() > 0, "empty holdout set");
    assert_eq!(holdout.n_features, model.n_features, "holdout feature count mismatch");
    let mut ge = GbtIntEngine::compile(model);

    let mut mismatches = 0usize;
    let mut correct_float = 0usize;
    let mut correct_int = 0usize;
    let mut per_class = vec![0.0f64; model.n_classes];
    let mut float_preds: Vec<u32> = Vec::with_capacity(holdout.n_rows());

    for i in 0..holdout.n_rows() {
        let row = holdout.row(i);
        let a = model.predict(row);
        let c = ge.predict(row);
        mismatches += usize::from(a != c);
        correct_float += usize::from(a == holdout.labels[i]);
        correct_int += usize::from(c == holdout.labels[i]);
        float_preds.push(a);
        for (k, (pf, pi)) in model.predict_proba(row).iter().zip(ge.predict_proba(row)).enumerate()
        {
            let err = (*pf as f64 - pi as f64).abs();
            if err > per_class[k] {
                per_class[k] = err;
            }
        }
    }

    let mut kernels = Vec::new();
    for kernel in TraversalKernel::all() {
        kernels.push(kernel.name().to_string());
        ge.set_kernel(kernel);
        for &backend in SimdBackend::available() {
            ge.set_backend(backend);
            let preds = ge.predict_batch(&holdout.features);
            mismatches += preds.iter().zip(&float_preds).filter(|(p, f)| p != f).count();
        }
    }

    let max_abs_error = per_class.iter().cloned().fold(0.0f64, f64::max);
    // Margin grid: every quantized value rounds within 2^-(shift+1), so
    // (T+1) accumulated terms stay within (T+1)/2^(shift+1); the softmax
    // *reporting* path runs in f32 on both sides, adding rounding noise
    // far above the grid term — the 1e-4 slack matches the engine's own
    // closeness test.
    let shift = ge.scale().shift;
    let grid = (model.trees.len() as f64 + 1.0) * (0.5f64).powi(shift as i32 + 1).max(f64::MIN_POSITIVE);
    let error_bound = grid + 1e-4;
    ParityVerdict {
        rows: holdout.n_rows(),
        mismatches,
        argmax_identical: mismatches == 0,
        kernels,
        engines: vec!["float-softmax".to_string(), "gbt-int".to_string()],
        max_abs_error,
        per_class_max_error: per_class,
        error_bound,
        within_bound: max_abs_error <= error_bound,
        accuracy_float: correct_float as f64 / holdout.n_rows() as f64,
        accuracy_int: correct_int as f64 / holdout.n_rows() as f64,
    }
}

/// Verify whichever kind `model` is (dispatch helper for the pipeline
/// orchestrator).
pub fn verify(model: &Model, holdout: &Dataset) -> ParityVerdict {
    match model.kind {
        ModelKind::RandomForest => verify_rf(model, holdout),
        ModelKind::Gbt => verify_gbt(model, holdout),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{train_gbt, ForestParams, GbtParams, RandomForest};

    #[test]
    fn rf_verdict_passes_on_trained_model() {
        let ds = shuttle_like(1000, 21);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
            21,
        );
        let v = verify_rf(&m, &ds);
        assert!(v.passed(), "{v:?}");
        assert_eq!(v.mismatches, 0);
        assert_eq!(v.rows, 1000);
        assert_eq!(v.kernels.len(), 3);
        assert_eq!(v.engines.len(), 3);
        assert!(v.max_abs_error <= v.error_bound, "{v:?}");
        assert!(v.max_abs_error > 0.0, "suspicious: exactly zero fixed-point error");
        assert!(v.accuracy_float > 0.5 && v.accuracy_int > 0.5);
        assert_eq!(v.accuracy_float, v.accuracy_int, "identical argmax => identical accuracy");
    }

    #[test]
    fn gbt_verdict_passes_on_trained_model() {
        let ds = shuttle_like(800, 22);
        let m = train_gbt(&ds, &GbtParams { n_rounds: 4, max_depth: 3, ..Default::default() }, 22);
        let v = verify_gbt(&m, &ds);
        assert!(v.passed(), "{v:?}");
        assert_eq!(v.mismatches, 0);
    }

    #[test]
    fn dispatch_matches_kind() {
        let ds = shuttle_like(300, 23);
        let rf = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 3, max_depth: 4, ..Default::default() },
            23,
        );
        assert!(verify(&rf, &ds).passed());
    }

    #[test]
    #[should_panic(expected = "empty holdout")]
    fn rejects_empty_holdout() {
        let ds = shuttle_like(200, 24);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 2, max_depth: 3, ..Default::default() },
            24,
        );
        let empty = crate::data::Dataset::new(vec![], vec![], ds.n_features, ds.n_classes);
        verify_rf(&m, &empty);
    }

    /// A corrupted integer representation must be *caught*: double one
    /// leaf's quantized values behind the engine's back is impossible
    /// from outside, so instead verify that a model whose probabilities
    /// are nearly tied still verifies (the hard case for argmax parity)
    /// — and that the verdict structure stays self-consistent.
    #[test]
    fn near_tie_still_verifies() {
        use crate::ir::{Node, Tree};
        let tree = |p: f32| Tree {
            nodes: vec![
                Node::Branch { feature: 0, threshold: 0.0, left: 1, right: 2 },
                Node::Leaf { values: vec![p, 1.0 - p] },
                Node::Leaf { values: vec![1.0 - p, p] },
            ],
        };
        let m = Model {
            kind: ModelKind::RandomForest,
            n_features: 1,
            n_classes: 2,
            trees: vec![tree(0.5000001), tree(0.4999999)],
            base_score: vec![0.0, 0.0],
        };
        m.validate().unwrap();
        let ds = Dataset::new(vec![-1.0, 1.0], vec![0, 1], 1, 2);
        let v = verify_rf(&m, &ds);
        assert!(v.argmax_identical, "{v:?}");
    }
}
