//! Pipeline report: one machine-readable `report.json` plus one human
//! `REPORT.md` per pipeline run.
//!
//! The JSON schema (format tag [`REPORT_FORMAT`]) is pinned by the
//! golden end-to-end test (`rust/tests/pipeline_golden.rs`): tools that
//! consume pipeline reports — dashboards, the EXPERIMENTS.md
//! paper-reproduction recipe, CI acceptance checks — can rely on the
//! key set not drifting silently.

use super::verify::ParityVerdict;
use crate::ir::stats::ModelStats;
use crate::util::json::{arr, num, obj, s, Json};

/// Format tag of `report.json` (bump on schema changes).
pub const REPORT_FORMAT: &str = "intreeger-pipeline-report-v1";

/// Dataset shape and split sizes.
#[derive(Clone, Debug)]
pub struct DatasetSummary {
    /// Total rows loaded.
    pub rows: usize,
    /// Feature columns.
    pub features: usize,
    /// Distinct classes.
    pub classes: usize,
    /// Rows in the training split.
    pub train_rows: usize,
    /// Rows in the verification holdout.
    pub holdout_rows: usize,
    /// Where the data came from (e.g. `csv:data.csv`, `synthetic:shuttle`).
    pub source: String,
}

/// How the model's leaf values were converted to integers.
#[derive(Clone, Debug)]
pub enum QuantSummary {
    /// RF probability leaves → `u32` fixed point, scale `2^32/n` (§III-A).
    ProbU32 {
        /// The scaling factor `2^32 / n_trees`.
        scale_factor: f64,
        /// Paper bound `n/2^32` on the accumulated probability error.
        error_bound: f64,
        /// Whether the bound beats f32's `2^-24` (`n <= 256`).
        beats_f32: bool,
    },
    /// GBT margin leaves → `i64` fixed point, power-of-two shift.
    MarginI64 {
        /// The power-of-two exponent of the margin scale.
        shift: u32,
    },
}

/// The generated-C artifact of one model.
#[derive(Clone, Debug)]
pub struct CodegenSummary {
    /// Code layout emitted (`ifelse`, `native`, ...).
    pub layout: String,
    /// Numeric variant emitted (always `intreeger` in the pipeline).
    pub variant: String,
    /// File name inside the output directory.
    pub file: String,
    /// Source size in bytes.
    pub bytes: usize,
    /// True when gcc compiled the C and its outputs matched the integer
    /// engine bit-for-bit on holdout rows (false when gcc is absent).
    pub gcc_checked: bool,
}

/// One kernel's measured batched throughput on the holdout.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Traversal kernel name.
    pub kernel: String,
    /// Min-of-k nanoseconds per row.
    pub ns_per_row: f64,
    /// Rows per second at the min-of-k time.
    pub rows_per_s: f64,
}

/// One simulated (core, variant) cycle estimate.
#[derive(Clone, Debug)]
pub struct SimRow {
    /// Core name (Table I).
    pub core: String,
    /// Numeric variant simulated.
    pub variant: String,
    /// Average dynamic instructions per inference.
    pub instructions: f64,
    /// Average cycles per inference.
    pub cycles: f64,
    /// Wall-clock microseconds per inference at the core's frequency.
    pub us_per_inference: f64,
}

/// Everything the pipeline learned about one trained model.
#[derive(Clone, Debug)]
pub struct ModelReport {
    /// `"rf"` or `"gbt"`.
    pub kind: String,
    /// Trees (RF) or boosting rounds (GBT) requested.
    pub n_trees_param: usize,
    /// Depth limit requested.
    pub max_depth_param: usize,
    /// Model file name inside the output directory.
    pub model_file: String,
    /// Structural statistics from [`crate::ir::stats`].
    pub stats: ModelStats,
    /// The float-vs-integer parity verdict.
    pub parity: ParityVerdict,
    /// Integer conversion parameters.
    pub quant: QuantSummary,
    /// Generated C artifact (None for GBT — C generation currently
    /// targets RF probability models).
    pub codegen: Option<CodegenSummary>,
    /// Kernel throughput measurements (empty when benching is off).
    pub bench: Vec<BenchRow>,
    /// Per-core cycle estimates (empty unless requested).
    pub simarch: Vec<SimRow>,
}

/// The execution environment the pipeline's verification and bench
/// stages ran under (additive in `intreeger-pipeline-report-v1`).
///
/// Deliberately records the *configured* strategy — the default
/// traversal kernel and the resolved SIMD backend — not a timed
/// calibration winner: report.json is bit-reproducible per host, and a
/// timing race deciding a recorded field would break that (the serving
/// coordinator's metrics snapshot carries the calibrated winner).
#[derive(Clone, Debug)]
pub struct ExecutionSummary {
    /// Default traversal kernel the verification sweep centers on.
    pub kernel: String,
    /// SIMD backend the run resolved (env override or best detected).
    pub backend: String,
    /// Intra-batch thread count the run resolved (env override or the
    /// single-thread default).
    pub threads: usize,
    /// CPU SIMD features detected on the host that produced the report.
    pub detected_features: Vec<String>,
}

/// The full pipeline report.
#[derive(Clone, Debug)]
pub struct Report {
    /// Seed every stochastic stage derived from.
    pub seed: u64,
    /// Dataset shape and split.
    pub dataset: DatasetSummary,
    /// Execution environment (kernel / SIMD backend / host features).
    pub execution: ExecutionSummary,
    /// One entry per trained model kind.
    pub models: Vec<ModelReport>,
}

impl Report {
    /// True when every model's parity verdict passed.
    pub fn all_verified(&self) -> bool {
        self.models.iter().all(|m| m.parity.passed())
    }

    /// Serialize to the pinned `report.json` schema.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("format", s(REPORT_FORMAT)),
            ("seed", num(self.seed as f64)),
            ("verified", Json::Bool(self.all_verified())),
            (
                "dataset",
                obj(vec![
                    ("rows", num(self.dataset.rows as f64)),
                    ("features", num(self.dataset.features as f64)),
                    ("classes", num(self.dataset.classes as f64)),
                    ("train_rows", num(self.dataset.train_rows as f64)),
                    ("holdout_rows", num(self.dataset.holdout_rows as f64)),
                    ("source", s(&self.dataset.source)),
                ]),
            ),
            (
                "execution",
                obj(vec![
                    ("kernel", s(&self.execution.kernel)),
                    ("backend", s(&self.execution.backend)),
                    ("threads", num(self.execution.threads as f64)),
                    (
                        "detected_features",
                        arr(self.execution.detected_features.iter().map(|f| s(f))),
                    ),
                ]),
            ),
            ("models", arr(self.models.iter().map(model_json))),
        ])
    }

    /// Render the human-readable `REPORT.md`.
    pub fn to_markdown(&self) -> String {
        let mut md = String::new();
        md.push_str("# InTreeger pipeline report\n\n");
        md.push_str(&format!(
            "- overall verdict: **{}**\n- seed: {}\n- dataset: {} rows ({} train / {} holdout), \
             {} features, {} classes (source: {})\n\n",
            if self.all_verified() { "PASS" } else { "FAIL" },
            self.seed,
            self.dataset.rows,
            self.dataset.train_rows,
            self.dataset.holdout_rows,
            self.dataset.features,
            self.dataset.classes,
            self.dataset.source
        ));
        md.push_str(&format!(
            "- execution: kernel {} on the {} backend with {} intra-batch thread(s) \
             (host SIMD features: {})\n\n",
            self.execution.kernel,
            self.execution.backend,
            self.execution.threads,
            if self.execution.detected_features.is_empty() {
                "none".to_string()
            } else {
                self.execution.detected_features.join(", ")
            }
        ));
        for m in &self.models {
            md.push_str(&model_markdown(m));
        }
        md.push_str(
            "---\n\nGenerated by `intreeger pipeline`. The parity verdict checks the paper's \
             \"no loss of precision\" claim: integer-only predictions must be argmax-identical \
             to the float reference on every holdout row, across every engine and traversal \
             kernel, with fixed-point probability error within the documented bound.\n",
        );
        md
    }
}

fn model_json(m: &ModelReport) -> Json {
    let p = &m.parity;
    let quant = match &m.quant {
        QuantSummary::ProbU32 { scale_factor, error_bound, beats_f32 } => obj(vec![
            ("scheme", s("prob-u32")),
            ("scale_factor", num(*scale_factor)),
            ("error_bound", num(*error_bound)),
            ("beats_f32", Json::Bool(*beats_f32)),
        ]),
        QuantSummary::MarginI64 { shift } => {
            obj(vec![("scheme", s("margin-i64")), ("shift", num(*shift as f64))])
        }
    };
    let codegen = match &m.codegen {
        Some(c) => obj(vec![
            ("layout", s(&c.layout)),
            ("variant", s(&c.variant)),
            ("file", s(&c.file)),
            ("bytes", num(c.bytes as f64)),
            ("gcc_checked", Json::Bool(c.gcc_checked)),
        ]),
        None => Json::Null,
    };
    obj(vec![
        ("kind", s(&m.kind)),
        (
            "params",
            obj(vec![
                ("n_trees", num(m.n_trees_param as f64)),
                ("max_depth", num(m.max_depth_param as f64)),
            ]),
        ),
        ("model_file", s(&m.model_file)),
        (
            "stats",
            obj(vec![
                ("n_trees", num(m.stats.n_trees as f64)),
                ("n_nodes", num(m.stats.n_nodes as f64)),
                ("n_branches", num(m.stats.n_branches as f64)),
                ("n_leaves", num(m.stats.n_leaves as f64)),
                ("max_depth", num(m.stats.max_depth as f64)),
                ("mean_leaf_depth", num(m.stats.mean_leaf_depth)),
                ("min_nonzero_leaf_prob", num(m.stats.min_nonzero_leaf_prob as f64)),
                ("qs_eligible_trees", num(m.stats.qs_eligible_trees as f64)),
            ]),
        ),
        (
            "accuracy",
            obj(vec![("float", num(p.accuracy_float)), ("int", num(p.accuracy_int))]),
        ),
        (
            "parity",
            obj(vec![
                ("rows", num(p.rows as f64)),
                ("mismatches", num(p.mismatches as f64)),
                ("argmax_identical", Json::Bool(p.argmax_identical)),
                ("kernels", arr(p.kernels.iter().map(|k| s(k)))),
                ("engines", arr(p.engines.iter().map(|e| s(e)))),
                ("per_class_max_error", arr(p.per_class_max_error.iter().map(|&e| num(e)))),
                ("max_abs_error", num(p.max_abs_error)),
                ("error_bound", num(p.error_bound)),
                ("within_bound", Json::Bool(p.within_bound)),
            ]),
        ),
        ("quant", quant),
        ("codegen", codegen),
        (
            "bench",
            arr(m.bench.iter().map(|b| {
                obj(vec![
                    ("kernel", s(&b.kernel)),
                    ("ns_per_row", num(b.ns_per_row)),
                    ("rows_per_s", num(b.rows_per_s)),
                ])
            })),
        ),
        (
            "simarch",
            arr(m.simarch.iter().map(|r| {
                obj(vec![
                    ("core", s(&r.core)),
                    ("variant", s(&r.variant)),
                    ("instructions", num(r.instructions)),
                    ("cycles", num(r.cycles)),
                    ("us_per_inference", num(r.us_per_inference)),
                ])
            })),
        ),
    ])
}

fn model_markdown(m: &ModelReport) -> String {
    let p = &m.parity;
    let mut md = format!(
        "## Model `{}` ({} trees requested, max depth {})\n\n",
        m.kind, m.n_trees_param, m.max_depth_param
    );
    md.push_str(&format!(
        "**Parity verdict: {}** — {} holdout rows, {} mismatches across engines {} × kernels \
         {} (per-row and batched); max fixed-point probability error {:.3e} vs bound {:.3e}.\n\n",
        if p.passed() { "PASS" } else { "FAIL" },
        p.rows,
        p.mismatches,
        p.engines.join("/"),
        p.kernels.join("/"),
        p.max_abs_error,
        p.error_bound,
    ));
    md.push_str("| metric | value |\n|---|---|\n");
    md.push_str(&format!("| accuracy (float reference) | {:.4} |\n", p.accuracy_float));
    md.push_str(&format!("| accuracy (integer-only) | {:.4} |\n", p.accuracy_int));
    md.push_str(&format!(
        "| trees / nodes / leaves | {} / {} / {} |\n",
        m.stats.n_trees, m.stats.n_nodes, m.stats.n_leaves
    ));
    md.push_str(&format!(
        "| depth (max / mean leaf) | {} / {:.2} |\n",
        m.stats.max_depth, m.stats.mean_leaf_depth
    ));
    md.push_str(&format!(
        "| quickscorer-eligible trees | {}/{} |\n",
        m.stats.qs_eligible_trees, m.stats.n_trees
    ));
    match &m.quant {
        QuantSummary::ProbU32 { scale_factor, error_bound, beats_f32 } => {
            md.push_str(&format!("| fixed-point scale 2^32/n | {scale_factor:.1} |\n"));
            md.push_str(&format!(
                "| paper error bound n/2^32 | {error_bound:.3e} (beats f32: {beats_f32}) |\n"
            ));
        }
        QuantSummary::MarginI64 { shift } => {
            md.push_str(&format!("| margin fixed-point shift | 2^{shift} |\n"));
        }
    }
    match &m.codegen {
        Some(c) => md.push_str(&format!(
            "| generated C | `{}` ({} bytes, layout {}, variant {}, gcc parity {}) |\n",
            c.file,
            c.bytes,
            c.layout,
            c.variant,
            if c.gcc_checked { "checked" } else { "not run" }
        )),
        None => md.push_str("| generated C | (skipped — C generation targets RF models) |\n"),
    }
    md.push('\n');
    if !m.bench.is_empty() {
        md.push_str("### Batched throughput (holdout, integer engine)\n\n");
        md.push_str("| kernel | ns/row | rows/s |\n|---|---|---|\n");
        for b in &m.bench {
            md.push_str(&format!(
                "| {} | {:.1} | {:.0} |\n",
                b.kernel, b.ns_per_row, b.rows_per_s
            ));
        }
        md.push('\n');
    }
    if !m.simarch.is_empty() {
        md.push_str("### Simulated per-core cost (trace-driven model)\n\n");
        md.push_str("| core | variant | instructions | cycles | us/inference |\n|---|---|---|---|---|\n");
        for r in &m.simarch {
            md.push_str(&format!(
                "| {} | {} | {:.1} | {:.1} | {:.3} |\n",
                r.core, r.variant, r.instructions, r.cycles, r.us_per_inference
            ));
        }
        md.push('\n');
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;

    fn verdict() -> ParityVerdict {
        ParityVerdict {
            rows: 100,
            mismatches: 0,
            argmax_identical: true,
            kernels: vec!["branchy".into(), "branchless".into(), "quickscorer".into()],
            engines: vec!["float".into(), "flint".into(), "intreeger".into()],
            per_class_max_error: vec![1e-9, 2e-9],
            max_abs_error: 2e-9,
            error_bound: 3e-9,
            within_bound: true,
            accuracy_float: 0.97,
            accuracy_int: 0.97,
        }
    }

    fn report() -> Report {
        Report {
            seed: 42,
            dataset: DatasetSummary {
                rows: 400,
                features: 7,
                classes: 7,
                train_rows: 300,
                holdout_rows: 100,
                source: "synthetic:shuttle".into(),
            },
            execution: ExecutionSummary {
                kernel: "branchless".into(),
                backend: "avx2".into(),
                threads: 2,
                detected_features: vec!["sse2".into(), "avx2".into()],
            },
            models: vec![ModelReport {
                kind: "rf".into(),
                n_trees_param: 10,
                max_depth_param: 6,
                model_file: "model_rf.json".into(),
                stats: crate::ir::stats::stats(&crate::trees::RandomForest::train(
                    &crate::data::shuttle_like(200, 1),
                    &crate::trees::ForestParams { n_trees: 2, max_depth: 3, ..Default::default() },
                    1,
                )),
                parity: verdict(),
                quant: QuantSummary::ProbU32 {
                    scale_factor: 4.29e8,
                    error_bound: 2.3e-9,
                    beats_f32: true,
                },
                codegen: Some(CodegenSummary {
                    layout: "ifelse".into(),
                    variant: "intreeger".into(),
                    file: "model_rf.c".into(),
                    bytes: 1234,
                    gcc_checked: false,
                }),
                bench: vec![BenchRow {
                    kernel: "branchless".into(),
                    ns_per_row: 120.0,
                    rows_per_s: 8.3e6,
                }],
                simarch: vec![],
            }],
        }
    }

    #[test]
    fn json_roundtrips_and_carries_format() {
        let r = report();
        let text = r.to_json().to_string();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("format").and_then(Json::as_str), Some(REPORT_FORMAT));
        assert_eq!(v.get("verified"), Some(&Json::Bool(true)));
        let models = v.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("kind").and_then(Json::as_str), Some("rf"));
        assert!(models[0].get("parity").unwrap().get("argmax_identical").is_some());
        let exec = v.get("execution").unwrap();
        assert_eq!(exec.get("kernel").and_then(Json::as_str), Some("branchless"));
        assert_eq!(exec.get("backend").and_then(Json::as_str), Some("avx2"));
        assert_eq!(exec.get("threads").and_then(Json::as_f64), Some(2.0));
        assert_eq!(exec.get("detected_features").and_then(Json::as_arr).unwrap().len(), 2);
    }

    #[test]
    fn markdown_carries_verdict_and_tables() {
        let md = report().to_markdown();
        assert!(md.contains("# InTreeger pipeline report"));
        assert!(md.contains("**PASS**"));
        assert!(md.contains("Parity verdict: PASS"));
        assert!(md.contains("| accuracy (float reference) | 0.9700 |"));
        assert!(md.contains("branchless | 120.0"));
        assert!(md.contains("execution: kernel branchless on the avx2 backend with 2 intra-batch thread(s)"));
        assert!(md.contains("sse2, avx2"));
    }

    #[test]
    fn failed_verdict_renders_fail() {
        let mut r = report();
        r.models[0].parity.argmax_identical = false;
        r.models[0].parity.mismatches = 3;
        assert!(!r.all_verified());
        assert!(r.to_markdown().contains("Parity verdict: FAIL"));
        let v = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(v.get("verified"), Some(&Json::Bool(false)));
    }
}
