//! End-to-end pipeline: dataset → trained forest → quantized IR →
//! **verified** integer-only C, in one call (the paper's Fig 1 as a
//! single command).
//!
//! The paper's headline claim is end-to-end: the framework "takes a
//! training dataset as input, and outputs an architecture-agnostic
//! integer-only C implementation … without loss of precision". This
//! module is that loop, closed and machine-checked:
//!
//! 1. **Split** — a seeded, *stratified* train/holdout split
//!    ([`crate::data::Dataset::stratified_split`]) so rare classes are
//!    represented on both sides;
//! 2. **Train** — a Random Forest and/or GBT ([`crate::trees`]);
//! 3. **Quantize** — leaf probabilities → `u32` fixed point (margins →
//!    `i64`) via [`crate::quant`];
//! 4. **Verify** — the holdout runs through the f32 reference engine and
//!    every integer engine × traversal kernel; predictions must be
//!    argmax-identical and the fixed-point error must sit within the
//!    paper's `n/2^32` bound ([`verify`]);
//! 5. **Emit** — integer-only C for a chosen [`Layout`] (gcc-parity
//!    checked when a compiler is present) plus a
//!    [`crate::runtime::PipelineManifest`] artifact bundle the serving
//!    coordinator can boot from directly;
//! 6. **Report** — machine-readable `report.json` + human `REPORT.md`
//!    ([`report`]), with model statistics, accuracy float-vs-int, kernel
//!    throughput and (optionally) per-core cycle estimates.
//!
//! The CLI front-end is `intreeger pipeline --csv data.csv --target col
//! --out dir/`; see the repository README for the full quickstart.

pub mod report;
pub mod verify;

pub use report::{Report, REPORT_FORMAT};
pub use verify::ParityVerdict;

use crate::codegen::{self, Layout};
use crate::data::{csv, Dataset};
use crate::inference::{Engine as _, GbtIntEngine, IntEngine, SimdBackend, TraversalKernel, Variant};
use crate::ir::{Model, ModelKind};
use crate::quant;
use crate::runtime::{PipelineManifest, PipelineModelEntry};
use crate::simarch::{self, Core};
use crate::trees::{train_gbt, ForestParams, GbtParams, RandomForest};
use crate::util::bench::{black_box, measure_opts, BenchOpts};
use crate::util::Rng;
use report::{
    BenchRow, CodegenSummary, DatasetSummary, ExecutionSummary, ModelReport, QuantSummary, SimRow,
};
use std::path::{Path, PathBuf};

/// Pipeline configuration (everything except the dataset itself).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Fraction of rows held out for verification (stratified per class).
    pub holdout_frac: f64,
    /// Seed for the split and the trainers (bit-reproducible runs).
    pub seed: u64,
    /// Train a Random Forest (the paper's primary model family).
    pub train_rf: bool,
    /// Additionally train a gradient-boosted model.
    pub train_gbt: bool,
    /// Trees (RF) / boosting rounds (GBT).
    pub n_trees: usize,
    /// Depth limit for every tree.
    pub max_depth: usize,
    /// C code layout to emit for the RF model.
    pub layout: Layout,
    /// Measure batched throughput per traversal kernel on the holdout.
    /// Off by default (matching the CLI's opt-in `--bench`) — timed
    /// sweeps cost wall-clock and their rows are non-deterministic.
    pub bench: bool,
    /// Add trace-driven per-core cycle estimates (Table I cores).
    pub simulate: bool,
    /// Free-form dataset provenance recorded in the report.
    pub source: String,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            holdout_frac: 0.25,
            seed: 42,
            train_rf: true,
            train_gbt: false,
            n_trees: 10,
            max_depth: 6,
            layout: Layout::IfElse,
            bench: false,
            simulate: false,
            source: "unspecified".to_string(),
        }
    }
}

/// What a pipeline run produced.
#[derive(Clone, Debug)]
pub struct PipelineOutcome {
    /// The artifact directory (model JSON/C, `report.json`, `REPORT.md`,
    /// `manifest.json`, `holdout.csv`).
    pub out_dir: PathBuf,
    /// The full report (already written to disk as JSON and markdown).
    pub report: Report,
}

/// Run the end-to-end pipeline on an in-memory dataset, writing every
/// artifact into `out_dir` (created if missing).
///
/// Returns an error if configuration is invalid, any artifact cannot be
/// written, or — after the report files have been written — the parity
/// verification failed: a pipeline run that returns `Ok` **is** the
/// machine-checked "no loss of precision" verdict.
///
/// ```
/// use intreeger::pipeline::{run, PipelineConfig};
/// let ds = intreeger::data::shuttle_like(300, 7);
/// let out = std::env::temp_dir().join(format!("intreeger_doc_pipeline_{}", std::process::id()));
/// let cfg = PipelineConfig { n_trees: 3, max_depth: 3, bench: false, ..Default::default() };
/// let outcome = run(&ds, &out, &cfg).expect("pipeline");
/// assert!(outcome.report.all_verified());
/// assert!(out.join("report.json").is_file() && out.join("model_rf.c").is_file());
/// ```
pub fn run(ds: &Dataset, out_dir: &Path, cfg: &PipelineConfig) -> anyhow::Result<PipelineOutcome> {
    anyhow::ensure!(cfg.train_rf || cfg.train_gbt, "nothing to train: enable RF and/or GBT");
    anyhow::ensure!(cfg.n_trees > 0, "n_trees must be positive");
    anyhow::ensure!(cfg.max_depth > 0, "max_depth must be positive");
    anyhow::ensure!(
        cfg.holdout_frac > 0.0 && cfg.holdout_frac < 1.0,
        "holdout_frac must be in (0, 1), got {}",
        cfg.holdout_frac
    );
    anyhow::ensure!(ds.n_rows() >= 8, "dataset too small ({} rows)", ds.n_rows());
    // report.json / manifest.json store the seed as a JSON number (f64);
    // reject seeds that would silently round instead of recording a
    // bit-reproducibility value that does not reproduce.
    anyhow::ensure!(
        cfg.seed <= (1u64 << 53),
        "seed {} exceeds 2^53 and cannot round-trip through the JSON report exactly",
        cfg.seed
    );
    std::fs::create_dir_all(out_dir)?;

    // 1. Stratified, seeded split.
    let mut rng = Rng::new(cfg.seed ^ 0x51DE_CA5E);
    let (train, holdout) = ds.stratified_split(cfg.holdout_frac, &mut rng);
    anyhow::ensure!(
        train.n_rows() > 0 && holdout.n_rows() > 0,
        "split produced an empty side ({} train / {} holdout)",
        train.n_rows(),
        holdout.n_rows()
    );
    csv::write_file(&out_dir.join("holdout.csv"), &holdout)
        .map_err(|e| anyhow::anyhow!("writing holdout.csv: {e}"))?;

    // 2..5 per model kind. gcc-divergence failures are *deferred* so the
    // report still reaches disk (the inspectable-evidence contract);
    // configuration errors (e.g. an ineligible layout) abort immediately.
    let mut models = Vec::new();
    let mut entries = Vec::new();
    let mut deferred: Vec<String> = Vec::new();
    if cfg.train_rf {
        let model = RandomForest::train(
            &train,
            &ForestParams { n_trees: cfg.n_trees, max_depth: cfg.max_depth, ..Default::default() },
            cfg.seed,
        );
        let (mr, entry, defer) = process_model(&model, "rf", &holdout, out_dir, cfg)?;
        models.push(mr);
        entries.push(entry);
        deferred.extend(defer);
    }
    if cfg.train_gbt {
        let model = train_gbt(
            &train,
            &GbtParams { n_rounds: cfg.n_trees, max_depth: cfg.max_depth, ..Default::default() },
            cfg.seed,
        );
        let (mr, entry, defer) = process_model(&model, "gbt", &holdout, out_dir, cfg)?;
        models.push(mr);
        entries.push(entry);
        deferred.extend(defer);
    }

    // 6. Report + manifest — written even when verification failed, so
    // the failure is inspectable.
    let report = Report {
        seed: cfg.seed,
        dataset: DatasetSummary {
            rows: ds.n_rows(),
            features: ds.n_features,
            classes: ds.n_classes,
            train_rows: train.n_rows(),
            holdout_rows: holdout.n_rows(),
            source: cfg.source.clone(),
        },
        // The configured execution, not a timed winner — keeps the
        // report byte-reproducible per host (see ExecutionSummary docs).
        execution: ExecutionSummary {
            kernel: TraversalKernel::default().name().to_string(),
            backend: SimdBackend::resolve().name().to_string(),
            threads: crate::inference::parallel::resolve(),
            detected_features: SimdBackend::detected_features()
                .into_iter()
                .map(str::to_string)
                .collect(),
        },
        models,
    };
    std::fs::write(out_dir.join("report.json"), report.to_json().to_string())?;
    std::fs::write(out_dir.join("REPORT.md"), report.to_markdown())?;
    let manifest = PipelineManifest { seed: cfg.seed, report_file: "report.json".to_string(), models: entries };
    manifest.write(out_dir)?;

    anyhow::ensure!(
        report.all_verified(),
        "float-vs-integer parity verification FAILED — see {}",
        out_dir.join("REPORT.md").display()
    );
    anyhow::ensure!(
        deferred.is_empty(),
        "generated-C verification FAILED (report written): {}",
        deferred.join("; ")
    );
    Ok(PipelineOutcome { out_dir: out_dir.to_path_buf(), report })
}

/// Run the pipeline on a CSV file. `target` selects the label column by
/// header name (requires `has_header`) or zero-based index; `None` means
/// the last column.
pub fn run_csv(
    csv_path: &Path,
    has_header: bool,
    target: Option<&str>,
    out_dir: &Path,
    cfg: &PipelineConfig,
) -> anyhow::Result<PipelineOutcome> {
    let ds = csv::read_file_with_target(csv_path, has_header, target)
        .map_err(|e| anyhow::anyhow!("loading {}: {e}", csv_path.display()))?;
    let mut cfg = cfg.clone();
    cfg.source = format!("csv:{}", csv_path.display());
    run(&ds, out_dir, &cfg)
}

/// Stages 3–5 for one trained model: write the IR, verify parity,
/// summarize quantization, emit + gcc-check C (RF only), bench kernels,
/// simulate cores.
///
/// The third tuple element carries *deferred* failure messages (gcc
/// parity divergence): the caller writes the report first and fails the
/// run afterwards, so the evidence reaches disk. Hard errors (invalid
/// model, unwritable files, ineligible layout) return `Err` directly.
fn process_model(
    model: &Model,
    kind: &str,
    holdout: &Dataset,
    out_dir: &Path,
    cfg: &PipelineConfig,
) -> anyhow::Result<(ModelReport, PipelineModelEntry, Option<String>)> {
    model.validate().map_err(|e| anyhow::anyhow!("trained {kind} model invalid: {e}"))?;
    let model_file = format!("model_{kind}.json");
    std::fs::write(out_dir.join(&model_file), model.to_json())?;

    let stats = crate::ir::stats::stats(model);
    let parity = verify::verify(model, holdout);

    let quant_summary = match model.kind {
        ModelKind::RandomForest => QuantSummary::ProbU32 {
            scale_factor: quant::scale_factor(model.trees.len()),
            error_bound: quant::error_bound(model.trees.len()),
            beats_f32: quant::beats_f32(model.trees.len()),
        },
        ModelKind::Gbt => QuantSummary::MarginI64 { shift: quant::margin_scale(model).shift },
    };

    // Integer-only C, RF only (the C generator targets probability
    // models); gcc-parity checked when a compiler is present.
    let mut deferred: Option<String> = None;
    let codegen_summary = if model.kind == ModelKind::RandomForest {
        if cfg.layout == Layout::QuickScorer && !stats.qs_ineligible.is_empty() {
            anyhow::bail!(
                "layout quickscorer requires every tree to have <= {} leaves (trees {:?} exceed \
                 it) — use --layout native-predicated or lower --depth",
                crate::inference::QS_MAX_LEAVES,
                stats.qs_ineligible
            );
        }
        let src = codegen::generate(model, cfg.layout, Variant::IntTreeger);
        let c_file = format!("model_{kind}.c");
        std::fs::write(out_dir.join(&c_file), &src)?;
        // A divergence here is evidence, not a config error: record it
        // as unchecked + a deferred failure so the report (and the
        // offending .c file) land on disk before the run fails.
        let gcc_checked = if codegen::compile::gcc_available() {
            match gcc_parity_check(model, &src, holdout) {
                Ok(()) => true,
                Err(e) => {
                    deferred = Some(format!("{kind}: {e}"));
                    false
                }
            }
        } else {
            false
        };
        Some(CodegenSummary {
            layout: cfg.layout.name().to_string(),
            variant: Variant::IntTreeger.name().to_string(),
            file: c_file,
            bytes: src.len(),
            gcc_checked,
        })
    } else {
        None
    };

    let bench = if cfg.bench { bench_kernels(model, holdout) } else { Vec::new() };
    let simarch = if cfg.simulate && model.kind == ModelKind::RandomForest {
        simulate_cores(model, holdout)
    } else {
        Vec::new()
    };

    let entry = PipelineModelEntry {
        kind: kind.to_string(),
        model_file: model_file.clone(),
        c_file: codegen_summary.as_ref().map(|c| c.file.clone()),
        layout: cfg.layout.name().to_string(),
        variant: Variant::IntTreeger.name().to_string(),
    };
    Ok((
        ModelReport {
            kind: kind.to_string(),
            n_trees_param: cfg.n_trees,
            max_depth_param: cfg.max_depth,
            model_file,
            stats,
            parity,
            quant: quant_summary,
            codegen: codegen_summary,
            bench,
            simarch,
        },
        entry,
        deferred,
    ))
}

/// Compile the generated C with gcc and require bit-identical u32
/// accumulators against the integer engine on a holdout sample.
fn gcc_parity_check(model: &Model, src: &str, holdout: &Dataset) -> anyhow::Result<()> {
    let bin = codegen::CBinary::compile(
        src,
        Variant::IntTreeger,
        model.n_features,
        model.n_classes,
        "pipeline",
    )
    .map_err(|e| anyhow::anyhow!("gcc on generated C: {e}"))?;
    let n = holdout.n_rows().min(64);
    let rows = &holdout.features[..n * holdout.n_features];
    let got = bin.predict_u32(rows).map_err(|e| anyhow::anyhow!("running generated C: {e}"))?;
    let ie = IntEngine::compile(model);
    for (i, fixed) in got.iter().enumerate() {
        anyhow::ensure!(
            fixed == &ie.predict_fixed(holdout.row(i)),
            "generated C diverged from the integer engine at holdout row {i}"
        );
    }
    Ok(())
}

/// Min-of-k batched throughput of the integer engine per traversal
/// kernel, over (a capped slice of) the holdout.
fn bench_kernels(model: &Model, holdout: &Dataset) -> Vec<BenchRow> {
    let n = holdout.n_rows().min(2048);
    let flat = &holdout.features[..n * holdout.n_features];
    match model.kind {
        ModelKind::RandomForest => {
            let mut e = IntEngine::compile(model);
            bench_sweep(n as u64, |k| {
                e.set_kernel(k);
                black_box(e.predict_batch(flat));
            })
        }
        ModelKind::Gbt => {
            let mut e = GbtIntEngine::compile(model);
            bench_sweep(n as u64, |k| {
                e.set_kernel(k);
                black_box(e.predict_batch(flat));
            })
        }
    }
}

/// One measured row per traversal kernel. `run` sets the kernel and
/// executes one batch (re-setting the kernel per repetition is a plain
/// field store — negligible next to the forest walk it times).
fn bench_sweep(n_rows: u64, mut run: impl FnMut(TraversalKernel)) -> Vec<BenchRow> {
    let opts = BenchOpts { warmup: 1, reps: 5 };
    TraversalKernel::all()
        .into_iter()
        .map(|kernel| {
            let m = measure_opts(opts, n_rows, || run(kernel));
            BenchRow {
                kernel: kernel.name().to_string(),
                ns_per_row: m.per_item_ns(),
                rows_per_s: m.throughput_per_s(),
            }
        })
        .collect()
}

/// Trace-driven cycle estimates on the paper's four cores, all variants.
fn simulate_cores(model: &Model, holdout: &Dataset) -> Vec<SimRow> {
    let mut rows = Vec::new();
    for core in Core::all() {
        for v in Variant::all() {
            let r = simarch::simulate(model, holdout, v, core, 200);
            rows.push(SimRow {
                core: core.name().to_string(),
                variant: v.name().to_string(),
                instructions: r.instructions,
                cycles: r.cycles,
                us_per_inference: r.seconds() * 1e6,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;

    fn outdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("intreeger_pipeline_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn minimal_rf_run_produces_all_artifacts() {
        let ds = shuttle_like(600, 31);
        let out = outdir("rf");
        let cfg = PipelineConfig { n_trees: 4, max_depth: 4, bench: false, ..Default::default() };
        let o = run(&ds, &out, &cfg).expect("pipeline");
        assert!(o.report.all_verified());
        for f in ["model_rf.json", "model_rf.c", "report.json", "REPORT.md", "manifest.json", "holdout.csv"] {
            assert!(out.join(f).is_file(), "missing {f}");
        }
        // The bundle reloads end-to-end.
        let man = PipelineManifest::load(&out).unwrap();
        assert_eq!(man.models.len(), 1);
        let m = Model::from_json(&std::fs::read_to_string(out.join(&man.models[0].model_file)).unwrap()).unwrap();
        assert_eq!(m.trees.len(), 4);
        // Holdout CSV reloads with the original shape.
        let holdout = csv::read_file(&out.join("holdout.csv"), false).unwrap();
        assert_eq!(holdout.n_features, ds.n_features);
        assert_eq!(holdout.n_rows(), o.report.dataset.holdout_rows);
    }

    #[test]
    fn rf_plus_gbt_run_reports_both() {
        let ds = shuttle_like(600, 32);
        let out = outdir("both");
        let cfg = PipelineConfig {
            n_trees: 3,
            max_depth: 3,
            train_gbt: true,
            bench: false,
            ..Default::default()
        };
        let o = run(&ds, &out, &cfg).expect("pipeline");
        assert_eq!(o.report.models.len(), 2);
        assert_eq!(o.report.models[0].kind, "rf");
        assert_eq!(o.report.models[1].kind, "gbt");
        assert!(o.report.models[1].codegen.is_none(), "no C for GBT");
        assert!(out.join("model_gbt.json").is_file());
        assert!(!out.join("model_gbt.c").exists());
    }

    #[test]
    fn rejects_bad_config() {
        let ds = shuttle_like(100, 33);
        let out = outdir("bad");
        let none = PipelineConfig { train_rf: false, ..Default::default() };
        assert!(run(&ds, &out, &none).is_err());
        let frac = PipelineConfig { holdout_frac: 1.5, ..Default::default() };
        assert!(run(&ds, &out, &frac).is_err());
        let zero = PipelineConfig { n_trees: 0, ..Default::default() };
        assert!(run(&ds, &out, &zero).is_err());
    }

    #[test]
    fn bench_and_simulate_populate_report() {
        let ds = shuttle_like(400, 34);
        let out = outdir("bench");
        let cfg = PipelineConfig {
            n_trees: 2,
            max_depth: 3,
            bench: true,
            simulate: true,
            ..Default::default()
        };
        let o = run(&ds, &out, &cfg).expect("pipeline");
        let m = &o.report.models[0];
        assert_eq!(m.bench.len(), 3, "one row per kernel");
        assert!(m.bench.iter().all(|b| b.ns_per_row > 0.0));
        assert_eq!(m.simarch.len(), 12, "4 cores x 3 variants");
    }
}
