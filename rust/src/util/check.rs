//! Miniature property-based testing harness (proptest is unavailable in
//! the offline build environment, so the crate carries its own).
//!
//! [`for_all`] runs a property over `n` deterministic pseudo-random cases
//! drawn from a generator; on failure it reports the seed and case index
//! so the exact failing input can be reproduced by re-running the test.
//! Generators are plain closures over [`Rng`], composed with ordinary
//! Rust code — no macro DSL.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` on `cases` inputs drawn from `gen`. Panics (test failure)
/// with a reproducible diagnostic on the first counterexample.
pub fn for_all<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Like [`for_all`] with the default case count and a fixed per-test seed
/// derived from the property name (stable across runs).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    });
    for_all(name, DEFAULT_CASES, seed, gen, prop);
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generate an arbitrary finite f32 by bit pattern (covers denormals,
/// both zeros, full exponent range) — the generator FlInt's soundness
/// property must sweep.
pub fn finite_f32(rng: &mut Rng) -> f32 {
    loop {
        let x = f32::from_bits(rng.next_u32());
        if x.is_finite() {
            return x;
        }
    }
}

/// Uniform f32 in a range (for feature-like values).
pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
    rng.uniform_in(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_all("count", 50, 1, |r| r.next_u32(), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_diagnostics() {
        for_all("fails", 50, 1, |r| r.below(10), |&x| {
            if x < 9 {
                Ok(())
            } else {
                Err("x too big".into())
            }
        });
    }

    #[test]
    fn finite_f32_is_finite_and_diverse() {
        let mut rng = Rng::new(3);
        let mut neg = 0;
        for _ in 0..1000 {
            let x = finite_f32(&mut rng);
            assert!(x.is_finite());
            if x < 0.0 {
                neg += 1;
            }
        }
        assert!(neg > 300 && neg < 700, "sign balance off: {neg}");
    }

    #[test]
    fn check_is_deterministic() {
        // Two runs of the same named property see the same inputs.
        let mut first = Vec::new();
        check("det", |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second = Vec::new();
        check("det", |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
