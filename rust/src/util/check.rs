//! Miniature property-based testing harness (proptest is unavailable in
//! the offline build environment, so the crate carries its own).
//!
//! [`for_all`] runs a property over `n` deterministic pseudo-random cases
//! drawn from a generator; on failure it reports the seed and case index
//! so the exact failing input can be reproduced by re-running the test.
//! Generators are plain closures over [`Rng`], composed with ordinary
//! Rust code — no macro DSL.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` on `cases` inputs drawn from `gen`. Panics (test failure)
/// with a reproducible diagnostic on the first counterexample.
pub fn for_all<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Like [`for_all`] with the default case count and a fixed per-test seed
/// derived from the property name (stable across runs).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    });
    for_all(name, DEFAULT_CASES, seed, gen, prop);
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generate an arbitrary finite f32 by bit pattern (covers denormals,
/// both zeros, full exponent range) — the generator FlInt's soundness
/// property must sweep.
pub fn finite_f32(rng: &mut Rng) -> f32 {
    loop {
        let x = f32::from_bits(rng.next_u32());
        if x.is_finite() {
            return x;
        }
    }
}

/// Uniform f32 in a range (for feature-like values).
pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
    rng.uniform_in(lo, hi)
}

/// Random probability vector of length `nc` that passes IR validation
/// (every entry positive, sums to 1) — for hand-built test forests.
pub fn random_dist(rng: &mut Rng, nc: usize) -> Vec<f32> {
    let raw: Vec<f32> = (0..nc).map(|_| rng.uniform_in(0.05, 1.0)).collect();
    let sum: f32 = raw.iter().sum();
    raw.iter().map(|&x| x / sum).collect()
}

/// Hand-built balanced tree over exactly `n_leaves` leaves (split
/// `n/2` / `n - n/2` recursively) — the only way to pin leaf counts at
/// the QuickScorer 63/64/65-leaf u64-mask eligibility boundary, shared
/// by the unit and integration parity suites.
pub fn balanced_tree(
    rng: &mut Rng,
    n_leaves: usize,
    nf: usize,
    nc: usize,
) -> crate::ir::Tree {
    use crate::ir::Node;
    fn build(nodes: &mut Vec<Node>, rng: &mut Rng, n: usize, nf: usize, nc: usize) -> u32 {
        let idx = nodes.len() as u32;
        if n == 1 {
            let values = random_dist(rng, nc);
            nodes.push(Node::Leaf { values });
        } else {
            nodes.push(Node::Branch {
                feature: rng.below(nf) as u32,
                threshold: rng.uniform_in(-50.0, 50.0),
                left: 0,
                right: 0,
            });
            let l = build(nodes, rng, n / 2, nf, nc);
            let r = build(nodes, rng, n - n / 2, nf, nc);
            if let Node::Branch { left, right, .. } = &mut nodes[idx as usize] {
                *left = l;
                *right = r;
            }
        }
        idx
    }
    assert!(n_leaves >= 1);
    let mut nodes = Vec::new();
    build(&mut nodes, rng, n_leaves, nf, nc);
    crate::ir::Tree { nodes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_all("count", 50, 1, |r| r.next_u32(), |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_diagnostics() {
        for_all("fails", 50, 1, |r| r.below(10), |&x| {
            if x < 9 {
                Ok(())
            } else {
                Err("x too big".into())
            }
        });
    }

    #[test]
    fn finite_f32_is_finite_and_diverse() {
        let mut rng = Rng::new(3);
        let mut neg = 0;
        for _ in 0..1000 {
            let x = finite_f32(&mut rng);
            assert!(x.is_finite());
            if x < 0.0 {
                neg += 1;
            }
        }
        assert!(neg > 300 && neg < 700, "sign balance off: {neg}");
    }

    #[test]
    fn balanced_tree_pins_leaf_count_and_validates() {
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 63, 64, 65] {
            let t = balanced_tree(&mut rng, n, 3, 2);
            assert_eq!(t.n_leaves(), n);
        }
    }

    #[test]
    fn check_is_deterministic() {
        // Two runs of the same named property see the same inputs.
        let mut first = Vec::new();
        check("det", |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second = Vec::new();
        check("det", |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
