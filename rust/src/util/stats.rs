//! Statistics helpers used by benchmarks and the evaluation harnesses.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Maximum absolute value.
pub fn max_abs(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
}

/// `p`-quantile (nearest-rank) of an unsorted slice; p in [0,1].
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((s.len() as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize;
    s[idx]
}

/// Simple online latency histogram with fixed power-of-two microsecond
/// buckets; used by the coordinator's metrics.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    /// bucket i counts values in [2^i, 2^(i+1)) microseconds; bucket 0 is [0,2).
    buckets: [u64; 32],
    count: u64,
    sum: f64,
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value (microseconds; negatives clamp to 0).
    pub fn record(&mut self, value_us: f64) {
        let v = value_us.max(0.0);
        let b = if v < 1.0 { 0 } else { (v.log2().floor() as usize).min(31) };
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (self.count as f64 * p.clamp(0.0, 1.0)).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        (1u64 << 32) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mean_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
    }

    #[test]
    fn max_abs_works() {
        assert_eq!(max_abs(&[-3.0, 2.0]), 3.0);
        assert_eq!(max_abs(&[]), 0.0);
    }

    #[test]
    fn quantile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
    }

    #[test]
    fn histogram_records() {
        let mut h = Histogram::new();
        for v in [1.0, 10.0, 100.0, 1000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 277.75).abs() < 1e-9);
        assert!(h.quantile(0.5) >= 10.0);
        assert!(h.quantile(1.0) >= 1000.0);
    }
}
