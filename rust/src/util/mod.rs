//! Small shared utilities: deterministic PRNG, statistics helpers.

pub mod bench;
pub mod check;
pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
