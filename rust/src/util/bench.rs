//! Minimal benchmark harness (criterion is unavailable in the offline
//! build). Used by every `cargo bench` target (declared with
//! `harness = false` in Cargo.toml).
//!
//! Methodology: warmup runs, then `reps` timed runs; reports
//! **min-of-k** as the headline (the least-noise estimator of the true
//! cost on a time-shared machine — every run's noise is additive), with
//! median and mean alongside. Counts are configurable per invocation
//! ([`BenchOpts`]) and overridable from the environment
//! (`INTREEGER_BENCH_WARMUP` / `INTREEGER_BENCH_REPS`), so CI smoke runs
//! and serious sweeps share one binary. A `black_box` guard prevents the
//! optimizer from deleting the measured work.

use std::time::Instant;

/// Optimizer barrier (std::hint::black_box re-export for benches).
pub use std::hint::black_box;

/// Warmup / repetition counts for one measurement.
///
/// The defaults (5 warmup, 15 timed reps) replace the seed's `(2, 7)`
/// ad-hoc counts, which were too small for trustworthy speedup cells:
/// with 7 samples the median still carries scheduler noise, and two
/// warmups don't reliably fault in the node arrays and scratch buffers.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Untimed runs before measurement (page/cache/branch warmup).
    pub warmup: usize,
    /// Timed runs; min/median/mean are computed over these.
    pub reps: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 5, reps: 15 }
    }
}

impl BenchOpts {
    /// Defaults, overridden by `INTREEGER_BENCH_WARMUP` /
    /// `INTREEGER_BENCH_REPS` when set (clamped to at least 1 rep).
    pub fn from_env() -> BenchOpts {
        fn var(key: &str, default: usize) -> usize {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        }
        let d = BenchOpts::default();
        BenchOpts {
            warmup: var("INTREEGER_BENCH_WARMUP", d.warmup),
            reps: var("INTREEGER_BENCH_REPS", d.reps).max(1),
        }
    }
}

/// Result of one measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Fastest timed run (the headline estimator).
    pub min_ns: f64,
    /// Median timed run.
    pub median_ns: f64,
    /// Mean over timed runs.
    pub mean_ns: f64,
    /// Work items per run (ns are divided by this for per-item figures).
    pub items: u64,
}

impl Measurement {
    /// Headline per-item cost: min-of-k.
    pub fn per_item_ns(&self) -> f64 {
        self.min_ns / self.items.max(1) as f64
    }

    /// Median-based per-item cost (noise-inclusive; kept for context).
    pub fn per_item_ns_median(&self) -> f64 {
        self.median_ns / self.items.max(1) as f64
    }

    /// Headline throughput: items/s at the min-of-k run time.
    pub fn throughput_per_s(&self) -> f64 {
        if self.min_ns == 0.0 {
            0.0
        } else {
            self.items as f64 / (self.min_ns * 1e-9)
        }
    }
}

/// Time `f` (which processes `items` work units per call) with explicit
/// warmup/rep counts.
pub fn measure_opts<F: FnMut()>(opts: BenchOpts, items: u64, mut f: F) -> Measurement {
    for _ in 0..opts.warmup {
        f();
    }
    let reps = opts.reps.max(1);
    let mut samples: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Measurement { min_ns: min, median_ns: median, mean_ns: mean, items }
}

/// Time `f`: `warmup` untimed runs, then `reps` timed runs (explicit
/// counts; prefer [`measure_opts`] + [`BenchOpts::from_env`] in benches
/// so counts are tunable without a rebuild).
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, items: u64, f: F) -> Measurement {
    measure_opts(BenchOpts { warmup, reps }, items, f)
}

/// Print one bench row in a stable, greppable format (min-of-k headline,
/// median alongside).
pub fn report(name: &str, m: &Measurement) {
    println!(
        "bench {name:<44} {:>12.1} ns/item {:>14.0} items/s (min-of-k; median {:.1} ns/item)",
        m.per_item_ns(),
        m.throughput_per_s(),
        m.per_item_ns_median()
    );
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let m = measure(1, 5, 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(m.min_ns > 0.0);
        assert!(m.median_ns >= m.min_ns);
        assert!(m.items == 1000);
        assert!(m.per_item_ns() >= 0.0);
        black_box(acc);
    }

    #[test]
    fn throughput_inverse_of_min_latency() {
        let m = Measurement { min_ns: 100.0, median_ns: 200.0, mean_ns: 200.0, items: 10 };
        assert!((m.per_item_ns() - 10.0).abs() < 1e-9);
        assert!((m.per_item_ns_median() - 20.0).abs() < 1e-9);
        assert!((m.throughput_per_s() - 1e8).abs() < 1.0);
    }

    #[test]
    fn opts_defaults_and_env_clamp() {
        let d = BenchOpts::default();
        assert!(d.warmup >= 5 && d.reps >= 15, "counts must not regress below the fix");
        let m = measure_opts(BenchOpts { warmup: 0, reps: 0 }, 1, || {});
        assert!(m.min_ns >= 0.0); // reps clamped to 1 internally
    }
}
