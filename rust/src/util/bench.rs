//! Minimal benchmark harness (criterion is unavailable in the offline
//! build). Used by every `cargo bench` target (declared with
//! `harness = false` in Cargo.toml).
//!
//! Methodology: warmup runs, then `reps` timed runs; reports min / median
//! / mean. A `black_box` guard prevents the optimizer from deleting the
//! measured work.

use std::time::Instant;

/// Optimizer barrier (std::hint::black_box re-export for benches).
pub use std::hint::black_box;

/// Result of one measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Work items per run (ns are divided by this for per-item figures).
    pub items: u64,
}

impl Measurement {
    pub fn per_item_ns(&self) -> f64 {
        self.median_ns / self.items.max(1) as f64
    }

    pub fn throughput_per_s(&self) -> f64 {
        if self.median_ns == 0.0 {
            0.0
        } else {
            self.items as f64 / (self.median_ns * 1e-9)
        }
    }
}

/// Time `f` (which processes `items` work units per call): `warmup`
/// untimed runs, then `reps` timed runs.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, items: u64, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Measurement { min_ns: min, median_ns: median, mean_ns: mean, items }
}

/// Print one bench row in a stable, greppable format.
pub fn report(name: &str, m: &Measurement) {
    println!(
        "bench {name:<44} {:>12.1} ns/item {:>14.0} items/s (median over runs)",
        m.per_item_ns(),
        m.throughput_per_s()
    );
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let m = measure(1, 5, 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(m.min_ns > 0.0);
        assert!(m.median_ns >= m.min_ns);
        assert!(m.items == 1000);
        assert!(m.per_item_ns() >= 0.0);
        black_box(acc);
    }

    #[test]
    fn throughput_inverse_of_latency() {
        let m = Measurement { min_ns: 10.0, median_ns: 100.0, mean_ns: 100.0, items: 10 };
        assert!((m.per_item_ns() - 10.0).abs() < 1e-9);
        assert!((m.throughput_per_s() - 1e8).abs() < 1.0);
    }
}
