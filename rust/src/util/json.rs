//! Minimal JSON reader/writer (no external dependencies — the build
//! environment is offline and the framework is freestanding by design).
//!
//! Supports the subset the model-IR interchange format needs: objects,
//! arrays, strings (with escapes), f64 numbers, booleans, null. Numbers
//! are emitted with enough precision to round-trip `f32` exactly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64; integers survive below 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, PartialEq)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub offset: usize,
    /// Human-readable cause.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ---------------------------------------------------------

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The items, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup (`None` for missing keys and non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required-field accessor with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { offset: 0, msg: format!("missing field '{key}'") })
    }

    // -- writer ------------------------------------------------------------

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    // 17 significant digits round-trips any f64 (and f32).
                    let _ = write!(out, "{:e}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- parser ------------------------------------------------------------

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Array from any iterator of values.
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

/// Number value.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// String value (clones the slice).
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Array of f32 stored losslessly (via exact f64 widening).
pub fn f32_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs unsupported (not produced by
                            // our writer); reject cleanly.
                            let ch = char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?;
                            out.push(ch);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    if len == 0 || start + len > self.bytes.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| JsonError {
            offset: start,
            msg: format!("bad number '{text}'"),
        })
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3", "1e3"] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_structures() {
        let v = obj(vec![
            ("name", s("m\"odel\n")),
            ("xs", f32_arr(&[1.5, -0.25, 3.0e-9, f32::MAX])),
            ("n", num(42.0)),
            ("flag", Json::Bool(true)),
            ("nil", Json::Null),
        ]);
        let text = v.to_string();
        let v2 = Json::parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn f32_lossless() {
        let vals = [0.1f32, 1.0 / 3.0, f32::MIN_POSITIVE, 1e-40, 87.5, -123.456];
        let text = f32_arr(&vals).to_string();
        let parsed = Json::parse(&text).unwrap();
        for (i, item) in parsed.as_arr().unwrap().iter().enumerate() {
            let back = item.as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), vals[i].to_bits(), "value {i}");
        }
    }

    #[test]
    fn parse_whitespace_and_nesting() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : [ ] } ] } ").unwrap();
        assert!(v.get("a").is_some());
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    /// Fuzz: arbitrary near-JSON byte soup must never panic.
    #[test]
    fn prop_parser_never_panics() {
        crate::util::check::check(
            "json_fuzz",
            |r| {
                let n = r.below(80);
                (0..n)
                    .map(|_| b"{}[]\",:0123456789.eE+-truefalsn\\ "[r.below(31)] as char)
                    .collect::<String>()
            },
            |text| {
                let _ = Json::parse(text);
                Ok(())
            },
        );
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let esc = Json::parse("\"\\u0041\\t\"").unwrap();
        assert_eq!(esc.as_str().unwrap(), "A\t");
    }

    #[test]
    fn accessors() {
        let v = obj(vec![("k", num(3.0))]);
        assert_eq!(v.req("k").unwrap().as_usize(), Some(3));
        assert!(v.req("missing").is_err());
        assert_eq!(num(3.5).as_usize(), None);
        assert_eq!(num(-1.0).as_usize(), None);
    }
}
