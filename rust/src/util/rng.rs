//! Deterministic, dependency-free PRNG (SplitMix64 core).
//!
//! Every stochastic component in the crate (dataset synthesis, bootstrap
//! sampling, feature subsampling, train/test splits) draws from this
//! generator so that experiments are bit-reproducible from a seed — a
//! property the paper's accuracy-parity experiment (§IV-B, 10 randomized
//! splits) depends on.

/// SplitMix64 PRNG. Passes BigCrush when used as a 64-bit generator and is
/// trivially seedable; quality is far beyond what dataset synthesis needs.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.uniform() as f32) * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded sampling (Lemire); bias is negligible for
        // the n used here (< 2^32).
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as `f32` with mean/std.
    #[inline]
    pub fn gauss_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gauss() as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork an independent stream (for per-tree generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.uniform()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_indices_k_ge_n() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(5, 10);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(29);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
