//! FlInt: floating-point comparisons on the integer ALU (§II-D).
//!
//! Hakert et al. observed that IEEE-754 floats can be compared with
//! integer instructions after reinterpreting their bit patterns. For
//! non-negative floats the raw bits are already monotone; to cover the
//! whole finite range we use the standard *order-preserving* map
//!
//! ```text
//! ordered(x) = bits(x) ^ 0x8000_0000          if x >= +0.0
//!            = !bits(x)                        if x <  -0.0
//! ```
//!
//! which is a strictly monotone bijection from finite floats (with
//! -0.0 canonicalized to +0.0) to `u32`, so
//! `x <= t  ⇔  ordered(x) <= ordered(t)` as unsigned integers.
//!
//! The generated C (see [`crate::codegen`]) applies `ordered()` to each
//! feature once per inference (a shift/xor pair — integer ops only) and
//! embeds thresholds pre-transformed at code-generation time, exactly as
//! the paper embeds its reinterpreted split values as immediates
//! (Listing 2). When every training-set feature is non-negative the
//! transform degenerates to the raw-bits comparison the paper's listings
//! show (`(int)(0x42af0000)`), and the code generator emits that cheaper
//! form — see [`SplitEncoding`].

/// Canonicalize -0.0 to +0.0 (IEEE: they compare equal, but their bit
/// patterns do not — the map must send them to the same integer).
#[inline]
pub fn canon_zero(x: f32) -> f32 {
    if x == 0.0 {
        0.0
    } else {
        x
    }
}

/// Order-preserving map from finite `f32` to `u32`:
/// `a <= b  ⇔  ordered_u32(a) <= ordered_u32(b)`.
///
/// NaN inputs are not ordered by IEEE; this map sends them above +inf
/// (sign=0) or below -inf (sign=1). The IR forbids NaN thresholds
/// ([`crate::ir::IrError::NonFiniteThreshold`]), and NaN features take the
/// `else`/right branch in generated code (documented model behaviour).
///
/// Branchless (§Perf): `x + 0.0` canonicalizes -0.0 to +0.0 (IEEE
/// addition; not foldable away precisely because of that property), and
/// the sign is broadcast with an arithmetic shift instead of a branch.
#[inline]
pub fn ordered_u32(x: f32) -> u32 {
    let b = (x + 0.0).to_bits();
    b ^ (((b as i32 >> 31) as u32) | 0x8000_0000)
}

/// Inverse of [`ordered_u32`] (for debugging / tests).
#[inline]
pub fn ordered_u32_inv(v: u32) -> f32 {
    if v & 0x8000_0000 != 0 {
        f32::from_bits(v ^ 0x8000_0000)
    } else {
        f32::from_bits(!v)
    }
}

/// Signed-integer variant used when all values are known non-negative:
/// for `x, t >= +0.0`, `x <= t ⇔ bits(x) as i32 <= bits(t) as i32`.
/// This is the form in the paper's Listing 2 — raw bits as an `int`
/// immediate — and saves the two transform instructions per feature.
#[inline]
pub fn nonneg_bits_i32(x: f32) -> i32 {
    debug_assert!(x.is_sign_positive() || x == 0.0);
    canon_zero(x).to_bits() as i32
}

/// How the code generator encodes a split comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitEncoding {
    /// All features and thresholds non-negative: compare raw bits as
    /// signed ints (paper Listing 2; no per-feature transform needed).
    RawBitsNonNegative,
    /// General case: order-preserving transform on features (once per
    /// inference) + pre-transformed unsigned thresholds.
    OrderedUnsigned,
}

/// Pick the cheapest valid encoding given the model's threshold range and
/// the (training-observed or declared) feature range.
pub fn choose_encoding(min_threshold: f32, min_feature: f32) -> SplitEncoding {
    if min_threshold >= 0.0 && min_feature >= 0.0 {
        SplitEncoding::RawBitsNonNegative
    } else {
        SplitEncoding::OrderedUnsigned
    }
}

/// FlInt split evaluation in the ordered-u32 domain.
#[inline]
pub fn flint_le(x_ordered: u32, t_ordered: u32) -> bool {
    x_ordered <= t_ordered
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::check::{check, finite_f32};

    #[test]
    fn known_values() {
        // 87.5 = 0x42AF0000 (the paper's Listing 2 split value).
        assert_eq!((87.5f32).to_bits(), 0x42AF_0000);
        assert_eq!(nonneg_bits_i32(87.5), 0x42AF_0000);
    }

    #[test]
    fn zero_canonicalization() {
        assert_eq!(ordered_u32(0.0), ordered_u32(-0.0));
        assert!(flint_le(ordered_u32(0.0), ordered_u32(-0.0)));
        assert!(flint_le(ordered_u32(-0.0), ordered_u32(0.0)));
    }

    #[test]
    fn basic_order() {
        let vals = [-f32::MAX, -1.5, -1e-30, 0.0, 1e-30, 1.0, 87.5, f32::MAX];
        for w in vals.windows(2) {
            assert!(ordered_u32(w[0]) < ordered_u32(w[1]), "{} !< {}", w[0], w[1]);
        }
    }

    #[test]
    fn inverse_roundtrip() {
        for &x in &[-123.456f32, -0.0, 0.0, 1e-20, 3.14, f32::MAX, -f32::MAX] {
            let y = ordered_u32_inv(ordered_u32(x));
            assert_eq!(canon_zero(x).to_bits(), y.to_bits());
        }
    }

    #[test]
    fn encoding_choice() {
        assert_eq!(choose_encoding(0.5, 0.0), SplitEncoding::RawBitsNonNegative);
        assert_eq!(choose_encoding(-0.5, 0.0), SplitEncoding::OrderedUnsigned);
        assert_eq!(choose_encoding(0.5, -1.0), SplitEncoding::OrderedUnsigned);
    }

    /// The core FlInt soundness property over the full finite domain:
    /// integer comparison of transformed values == float comparison.
    #[test]
    fn prop_ordered_map_preserves_le_and_lt() {
        check(
            "ordered_map_preserves_le_lt",
            |r| (finite_f32(r), finite_f32(r)),
            |&(a, b)| {
                prop_ensure!(
                    (a <= b) == (ordered_u32(a) <= ordered_u32(b)),
                    "le mismatch: {a} vs {b}"
                );
                prop_ensure!(
                    (a < b) == (ordered_u32(a) < ordered_u32(b)),
                    "lt mismatch: {a} vs {b}"
                );
                Ok(())
            },
        );
    }

    /// Raw-bits signed comparison is sound on the non-negative domain.
    #[test]
    fn prop_raw_bits_sound_for_nonneg() {
        check(
            "raw_bits_nonneg",
            |r| {
                // bits in [0, 0x7F7F_FFFF] are non-negative finite floats
                let a = f32::from_bits((r.next_u32() >> 1).min(0x7F7F_FFFF));
                let b = f32::from_bits((r.next_u32() >> 1).min(0x7F7F_FFFF));
                (a, b)
            },
            |&(a, b)| {
                prop_ensure!(
                    (a <= b) == (nonneg_bits_i32(a) <= nonneg_bits_i32(b)),
                    "raw-bits mismatch: {a} vs {b}"
                );
                Ok(())
            },
        );
    }

    /// The map is a bijection on canonicalized finite floats.
    #[test]
    fn prop_ordered_map_bijective() {
        check(
            "ordered_map_bijective",
            |r| finite_f32(r),
            |&a| {
                let back = ordered_u32_inv(ordered_u32(a));
                prop_ensure!(
                    canon_zero(a).to_bits() == back.to_bits(),
                    "roundtrip failed for {a}"
                );
                Ok(())
            },
        );
    }

    /// Exhaustive boundary sweep around interesting exponent transitions —
    /// cheap insurance beyond random sampling.
    #[test]
    fn boundary_sweep() {
        let anchors: [f32; 8] =
            [0.0, f32::MIN_POSITIVE, 1.0, 87.5, f32::MAX, -1.0, -f32::MIN_POSITIVE, -f32::MAX];
        for &a in &anchors {
            // neighbours one ulp away in both directions
            let bits = a.to_bits();
            for d in [-2i64, -1, 0, 1, 2] {
                let nb = (bits as i64 + d).clamp(0, u32::MAX as i64) as u32;
                let b = f32::from_bits(nb);
                if !b.is_finite() {
                    continue;
                }
                assert_eq!((a <= b), ordered_u32(a) <= ordered_u32(b), "a={a} b={b}");
                assert_eq!((b <= a), ordered_u32(b) <= ordered_u32(a), "a={a} b={b}");
            }
        }
    }
}
