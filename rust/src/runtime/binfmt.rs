//! `INTB` — the zero-copy binary model format.
//!
//! A compiled forest is a handful of flat arrays (`Node8` packs, SoA
//! gather planes, leaf tables, QuickScorer condition streams). JSON
//! deserialization rebuilds all of them node by node on every boot; for
//! a fleet of hundreds of resident models that is the dominant load
//! cost. This module instead freezes the *compiled* layout on disk:
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------------
//!      0     4  magic "INTB"
//!      4     4  format version (1)
//!      8     4  endianness tag 0x0A0B0C0D (reads back byte-swapped
//!               when the file crosses byte orders)
//!     12     4  model kind: 0 = random forest, 1 = GBT
//!     16     4  n_features          20     4  n_classes
//!     24     4  n_trees             28     4  n_nodes
//!     32     4  n_leaves (payload rows of the leaf tables)
//!     36     4  node order: 0 = depth, 1 = breadth
//!     40     4  GBT margin scale shift (0 for RF)
//!     44     4  QS blocks           48     4  QS fallback trees
//!     52     4  QS conditions       56     4  QS leaf payload slots
//!     60     4  section count       64     8  total file length
//!     72    56  reserved (must be zero)
//!    128   16n  section table: (offset u64, length u64) per section
//!      …        sections, each 64-byte aligned, in fixed kind order
//! ```
//!
//! Loading ([`load`]) is bounds-check + validate + pointer-cast: every
//! section becomes a borrowed `&[T]` straight into the source bytes, no
//! per-node work. Because the traversal kernels index these arrays with
//! unchecked loads (their safety contract is the compile-time shape
//! invariants), the validator re-establishes **every** invariant the
//! walkers rely on before a cast slice escapes: section
//! alignment/bounds/non-overlap, tree-offset monotonicity, child
//! adjacency (`right = left + 1`, children strictly after their parent —
//! so traversal is acyclic), leaf self-loops and payload bounds, exact
//! per-tree depths (the branchless kernel's fixed trip count), SoA
//! planes mirroring the packed nodes, and the QuickScorer mask
//! invariant that keeps every final bitvector nonzero (so
//! `trailing_zeros` always lands inside the tree's payload range).
//! A hostile file is rejected with a typed [`BinError`]; loading never
//! panics and never reads past the buffer.
//!
//! Alignment: sections start on 64-byte boundaries, so any element type
//! up to 8-byte alignment casts cleanly **provided the base pointer is
//! 8-byte aligned**. [`load`] refuses unaligned bases
//! ([`BinError::Unaligned`]); [`OwnedBin`] copies arbitrary bytes into a
//! `u64`-backed buffer to guarantee the base alignment — the fallback
//! for sources like `Vec<u8>` file reads that promise none. On unix,
//! [`MappedBin`] (behind the portable [`FileBin`] wrapper) maps the
//! file with `mmap(2)` instead: the mapping base is page-aligned
//! (≥ 4096 bytes), so the 8-byte gate holds by construction, *no* heap
//! copy of the artifact is ever made, and fleet load cost is
//! O(validation) in resident memory too — file pages fault in on
//! demand. The existing structural re-validation is what makes this
//! safe: every invariant the unchecked-load kernels rely on is
//! re-established against the mapped bytes before a cast slice
//! escapes, exactly as for heap-resident sources.
//!
//! Byte order is native-with-a-tag: files are written in the host's
//! byte order and record [`ENDIAN_TAG`]; a file produced on the
//! opposite byte order fails with [`BinError::BadEndianness`] instead
//! of silently mis-reading — coherent with the pointer-cast read model
//! (no per-word swabbing on load).

use crate::flint::ordered_u32;
use crate::inference::compiled::{
    CompiledForest, Node8, NodeOrder, LEAF, LEAF_BIT, MAX_FEATURES, MAX_TREE_NODES,
};
use crate::inference::gbt_int::GbtEngineParts;
use crate::inference::quickscorer::{QsBlock, QsPlan, QS_MAX_LEAVES};
use crate::inference::GbtIntEngine;
use crate::ir::{Model, ModelKind, MAX_CLASSES, MAX_TREES};
use crate::quant::MarginScale;

/// File magic, first four bytes of every artifact.
pub const MAGIC: [u8; 4] = *b"INTB";
/// Current format version.
pub const VERSION: u32 = 1;
/// Byte-order tag written natively; reads back swapped across byte
/// orders.
pub const ENDIAN_TAG: u32 = 0x0A0B_0C0D;
/// Fixed header length in bytes; the section table starts here.
pub const HEADER_LEN: usize = 128;
/// Alignment of every section start.
pub const SECTION_ALIGN: usize = 64;

/// Largest GBT margin shift a file may declare (mirrors the
/// [`crate::quant::margin_scale`] clamp).
const MAX_SCALE_SHIFT: u32 = 40;
/// Section count of a random-forest artifact (14 model + 11 QS).
const RF_SECTIONS: usize = 25;
/// Section count of a GBT artifact (7 model + 11 QS).
const GBT_SECTIONS: usize = 18;

/// True when `bytes` begin with the `INTB` magic — the cheap format
/// sniff the JSON loader uses to give a typed format-confusion error
/// instead of a parse failure.
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= 4 && bytes[..4] == MAGIC
}

// ---------------------------------------------------------------------------
// Errors

/// Typed rejection of a binary artifact. Every invalid input maps to
/// one of these — loading never panics and never reads past the buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BinError {
    /// Fewer bytes than the fixed header + section table need.
    TooShort {
        /// Bytes required to go on parsing.
        need: usize,
        /// Bytes actually provided.
        got: usize,
    },
    /// First four bytes are not `INTB` (e.g. a JSON model was handed to
    /// the binary loader).
    BadMagic([u8; 4]),
    /// Unsupported format version.
    BadVersion(u32),
    /// Endianness tag mismatch — the file was written on a host with
    /// the opposite byte order.
    BadEndianness(u32),
    /// Unknown model-kind code.
    BadKind(u32),
    /// The base pointer is not 8-byte aligned; copy through
    /// [`OwnedBin`] instead.
    Unaligned,
    /// A fixed header field is out of range or inconsistent.
    BadHeader(String),
    /// A section-table entry or section length failed validation.
    BadSection {
        /// Section name (fixed per kind).
        name: &'static str,
        /// What was wrong.
        why: String,
    },
    /// Section contents violate a structural invariant the traversal
    /// kernels rely on.
    Malformed(String),
    /// The artifact is valid but of the other model kind.
    KindMismatch {
        /// Kind the caller asked to materialize.
        expected: &'static str,
        /// Kind the artifact holds.
        got: &'static str,
    },
}

impl std::fmt::Display for BinError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BinError::TooShort { need, got } => {
                write!(f, "binary model truncated: need at least {need} bytes, got {got}")
            }
            BinError::BadMagic(m) => write!(
                f,
                "not an INTB binary model (magic {m:02x?}); JSON models load via the IR deserializer"
            ),
            BinError::BadVersion(v) => {
                write!(f, "unsupported INTB format version {v} (this build reads version {VERSION})")
            }
            BinError::BadEndianness(tag) => write!(
                f,
                "endianness tag {tag:#010x} does not match this host (expected {ENDIAN_TAG:#010x}); the file was written on an opposite-byte-order machine"
            ),
            BinError::BadKind(k) => write!(f, "unknown model kind code {k}"),
            BinError::Unaligned => {
                write!(f, "buffer base is not 8-byte aligned; load through OwnedBin::from_bytes")
            }
            BinError::BadHeader(why) => write!(f, "invalid INTB header: {why}"),
            BinError::BadSection { name, why } => write!(f, "invalid section '{name}': {why}"),
            BinError::Malformed(why) => write!(f, "malformed model structure: {why}"),
            BinError::KindMismatch { expected, got } => {
                write!(f, "artifact holds a {got} model, not the requested {expected}")
            }
        }
    }
}
impl std::error::Error for BinError {}

// ---------------------------------------------------------------------------
// Raw byte reinterpretation

/// Marker for element types that reinterpret safely to/from raw bytes:
/// fixed layout, no padding, every bit pattern valid, alignment ≤ 8
/// (the guarantee [`load`] enforces on section starts).
unsafe trait Pod: Copy {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for f32 {}
// Node8 is #[repr(C)] { u32, u16, u16 }: size 8 equals the field sum,
// so there is no padding, and every bit pattern is a *representable*
// node — the canonical encoding is what the validator establishes.
unsafe impl Pod for Node8 {}

/// Byte view of a Pod slice (the write path's serializer: sections are
/// memcpy'd, never re-encoded element by element).
fn bytes_of<T: Pod>(s: &[T]) -> &[u8] {
    // SAFETY: Pod guarantees no padding and no invalid byte patterns,
    // and the length is exactly the slice's byte span.
    unsafe { std::slice::from_raw_parts(s.as_ptr().cast::<u8>(), std::mem::size_of_val(s)) }
}

// ---------------------------------------------------------------------------
// Writing

/// Append-only section writer: header and table are reserved up front,
/// sections land 64-byte aligned, then the table and total length are
/// backpatched. Deterministic — identical inputs produce identical
/// bytes (the round-trip byte-stability tests pin this).
struct Writer {
    buf: Vec<u8>,
    sections: Vec<(u64, u64)>,
    n_sections: usize,
}

impl Writer {
    fn new(header: [u8; HEADER_LEN], n_sections: usize) -> Writer {
        let mut buf = header.to_vec();
        buf.resize(HEADER_LEN + n_sections * 16, 0);
        Writer { buf, sections: Vec::with_capacity(n_sections), n_sections }
    }

    fn section<T: Pod>(&mut self, data: &[T]) {
        while self.buf.len() % SECTION_ALIGN != 0 {
            self.buf.push(0);
        }
        let off = self.buf.len() as u64;
        let b = bytes_of(data);
        self.buf.extend_from_slice(b);
        self.sections.push((off, b.len() as u64));
    }

    fn finish(mut self) -> Vec<u8> {
        assert_eq!(self.sections.len(), self.n_sections, "writer section count drifted");
        for (i, &(off, len)) in self.sections.iter().enumerate() {
            let at = HEADER_LEN + i * 16;
            self.buf[at..at + 8].copy_from_slice(&off.to_ne_bytes());
            self.buf[at + 8..at + 16].copy_from_slice(&len.to_ne_bytes());
        }
        let total = self.buf.len() as u64;
        self.buf[64..72].copy_from_slice(&total.to_ne_bytes());
        self.buf
    }
}

/// Fixed header fields (file length is backpatched by the writer).
struct Header {
    kind: u32,
    n_features: u32,
    n_classes: u32,
    n_trees: u32,
    n_nodes: u32,
    n_leaves: u32,
    order: u32,
    scale_shift: u32,
    qs_blocks: u32,
    qs_fallback: u32,
    qs_conds: u32,
    qs_payloads: u32,
    n_sections: u32,
}

fn build_header(h: &Header) -> [u8; HEADER_LEN] {
    let mut out = [0u8; HEADER_LEN];
    out[0..4].copy_from_slice(&MAGIC);
    let words = [
        VERSION,
        ENDIAN_TAG,
        h.kind,
        h.n_features,
        h.n_classes,
        h.n_trees,
        h.n_nodes,
        h.n_leaves,
        h.order,
        h.scale_shift,
        h.qs_blocks,
        h.qs_fallback,
        h.qs_conds,
        h.qs_payloads,
        h.n_sections,
    ];
    for (i, w) in words.iter().enumerate() {
        let at = 4 + i * 4;
        out[at..at + 4].copy_from_slice(&w.to_ne_bytes());
    }
    out
}

/// Per-plan QS totals: (trees in blocks, conditions, payload slots).
fn qs_totals(qs: &QsPlan) -> (usize, usize, usize) {
    let trees = qs.blocks.iter().map(|b| b.n_trees).sum();
    let conds = qs.blocks.iter().map(|b| b.masks.len()).sum();
    let payloads = qs.blocks.iter().map(|b| b.leaf_payloads.len()).sum();
    (trees, conds, payloads)
}

/// Append the 11 QuickScorer sections (shared by both kinds).
fn write_qs(w: &mut Writer, qs: &QsPlan) {
    let mut meta: Vec<u32> = Vec::with_capacity(qs.blocks.len() * 3);
    for b in &qs.blocks {
        meta.push(b.n_trees as u32);
        meta.push(b.masks.len() as u32);
        meta.push(b.leaf_payloads.len() as u32);
    }
    let cat_u32 = |f: fn(&QsBlock) -> &Vec<u32>| -> Vec<u32> {
        qs.blocks.iter().flat_map(|b| f(b).iter().copied()).collect()
    };
    let tree_ids = cat_u32(|b| &b.tree_ids);
    let init: Vec<u64> = qs.blocks.iter().flat_map(|b| b.init.iter().copied()).collect();
    let feature_offsets = cat_u32(|b| &b.feature_offsets);
    let thresh_ord = cat_u32(|b| &b.thresh_ord);
    let thresh_f32 = cat_u32(|b| &b.thresh_f32);
    let tree_of: Vec<u16> = qs.blocks.iter().flat_map(|b| b.tree_of.iter().copied()).collect();
    let masks: Vec<u64> = qs.blocks.iter().flat_map(|b| b.masks.iter().copied()).collect();
    let leaf_offsets = cat_u32(|b| &b.leaf_offsets);
    let payloads = cat_u32(|b| &b.leaf_payloads);
    w.section(&meta);
    w.section(&tree_ids);
    w.section(&init);
    w.section(&feature_offsets);
    w.section(&thresh_ord);
    w.section(&thresh_f32);
    w.section(&tree_of);
    w.section(&masks);
    w.section(&leaf_offsets);
    w.section(&payloads);
    w.section(&qs.fallback);
}

/// Serialize a compiled random forest. Deterministic; the inverse of
/// [`BinView::to_forest`].
pub fn write_forest(f: &CompiledForest) -> Vec<u8> {
    let n_leaves = f.leaf_f32.len() / f.n_classes;
    let (_, qs_conds, qs_payloads) = qs_totals(&f.qs);
    let header = build_header(&Header {
        kind: 0,
        n_features: f.n_features as u32,
        n_classes: f.n_classes as u32,
        n_trees: f.n_trees as u32,
        n_nodes: f.n_nodes() as u32,
        n_leaves: n_leaves as u32,
        order: match f.order {
            NodeOrder::Depth => 0,
            NodeOrder::Breadth => 1,
        },
        scale_shift: 0,
        qs_blocks: f.qs.blocks.len() as u32,
        qs_fallback: f.qs.fallback.len() as u32,
        qs_conds: qs_conds as u32,
        qs_payloads: qs_payloads as u32,
        n_sections: RF_SECTIONS as u32,
    });
    let mut w = Writer::new(header, RF_SECTIONS);
    w.section(&f.tree_offsets);
    w.section(&f.tree_depths);
    w.section(&f.feature);
    w.section(&f.thresh_f32);
    w.section(&f.thresh_ord);
    w.section(&f.left);
    w.section(&f.right);
    w.section(&f.leaf_f32);
    w.section(&f.leaf_u32);
    w.section(&f.nodes_f32);
    w.section(&f.nodes_ord);
    w.section(&f.soa_tw_ord);
    w.section(&f.soa_tw_f32);
    w.section(&f.soa_ffl);
    write_qs(&mut w, &f.qs);
    w.finish()
}

/// Serialize a compiled GBT engine. Deterministic; the inverse of
/// [`BinView::to_gbt`].
pub fn write_gbt(e: &GbtIntEngine) -> Vec<u8> {
    let p = e.parts();
    let n_leaves = p.leaf_q.len() / p.n_classes;
    let (_, qs_conds, qs_payloads) = qs_totals(p.qs);
    let header = build_header(&Header {
        kind: 1,
        n_features: p.n_features as u32,
        n_classes: p.n_classes as u32,
        n_trees: (p.tree_offsets.len() - 1) as u32,
        n_nodes: p.nodes.len() as u32,
        n_leaves: n_leaves as u32,
        order: 1, // the GBT compiler always packs breadth-first
        scale_shift: p.scale.shift,
        qs_blocks: p.qs.blocks.len() as u32,
        qs_fallback: p.qs.fallback.len() as u32,
        qs_conds: qs_conds as u32,
        qs_payloads: qs_payloads as u32,
        n_sections: GBT_SECTIONS as u32,
    });
    let mut w = Writer::new(header, GBT_SECTIONS);
    w.section(p.tree_offsets);
    w.section(p.tree_depths);
    w.section(p.nodes);
    w.section(p.soa_tw);
    w.section(p.soa_ffl);
    w.section(p.leaf_q);
    w.section(p.base_q);
    write_qs(&mut w, p.qs);
    w.finish()
}

/// Compile an IR model and serialize it (RF with the engines' default
/// depth-first layout; GBT with its canonical breadth-first one).
pub fn write_model(model: &Model) -> Vec<u8> {
    match model.kind {
        ModelKind::RandomForest => write_forest(&CompiledForest::compile(model)),
        ModelKind::Gbt => write_gbt(&GbtIntEngine::compile(model)),
    }
}

// ---------------------------------------------------------------------------
// Loading

/// Model kind stored in an artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinKind {
    /// Random forest (probability-averaging leaf tables).
    Rf,
    /// Gradient-boosted trees (fixed-point margin leaf tables).
    Gbt,
}

impl BinKind {
    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            BinKind::Rf => "rf",
            BinKind::Gbt => "gbt",
        }
    }
}

/// Borrowed random-forest sections, in file order.
struct RfSections<'a> {
    tree_offsets: &'a [u32],
    tree_depths: &'a [u32],
    feature: &'a [u32],
    thresh_f32: &'a [f32],
    thresh_ord: &'a [u32],
    left: &'a [u32],
    right: &'a [u32],
    leaf_f32: &'a [f32],
    leaf_u32: &'a [u32],
    nodes_f32: &'a [Node8],
    nodes_ord: &'a [Node8],
    soa_tw_ord: &'a [u32],
    soa_tw_f32: &'a [u32],
    soa_ffl: &'a [u32],
}

/// Borrowed GBT sections, in file order.
struct GbtSections<'a> {
    tree_offsets: &'a [u32],
    tree_depths: &'a [u32],
    nodes: &'a [Node8],
    soa_tw: &'a [u32],
    soa_ffl: &'a [u32],
    leaf_q: &'a [i64],
    base_q: &'a [i64],
}

/// Borrowed QuickScorer sections (flattened across blocks).
struct QsSections<'a> {
    meta: &'a [u32],
    tree_ids: &'a [u32],
    init: &'a [u64],
    feature_offsets: &'a [u32],
    thresh_ord: &'a [u32],
    thresh_f32: &'a [u32],
    tree_of: &'a [u16],
    masks: &'a [u64],
    leaf_offsets: &'a [u32],
    payloads: &'a [u32],
    fallback: &'a [u32],
}

enum Body<'a> {
    Rf(RfSections<'a>),
    Gbt(GbtSections<'a>),
}

/// A validated, zero-copy view of a binary model: borrowed slices into
/// the source bytes plus the decoded header. Materialize with
/// [`Self::to_forest`] / [`Self::to_gbt`] — bulk copies of the
/// validated slices, still no per-node deserialization.
pub struct BinView<'a> {
    kind: BinKind,
    n_features: usize,
    n_classes: usize,
    n_trees: usize,
    n_nodes: usize,
    n_leaves: usize,
    order: NodeOrder,
    scale_shift: u32,
    resident_bytes: usize,
    body: Body<'a>,
    qs: QsSections<'a>,
}

/// Sequential section reader: walks the table in the fixed kind order,
/// enforcing exact lengths, 64-byte alignment, in-bounds extents, and
/// strictly forward (non-overlapping) placement.
struct Cursor<'a> {
    bytes: &'a [u8],
    idx: usize,
    min_off: usize,
}

impl<'a> Cursor<'a> {
    fn take<T: Pod>(&mut self, name: &'static str, count: usize) -> Result<&'a [T], BinError> {
        let at = HEADER_LEN + self.idx * 16;
        self.idx += 1;
        let off64 = u64::from_ne_bytes(self.bytes[at..at + 8].try_into().unwrap());
        let len64 = u64::from_ne_bytes(self.bytes[at + 8..at + 16].try_into().unwrap());
        let off = usize::try_from(off64)
            .map_err(|_| BinError::BadSection { name, why: format!("offset {off64} overflows") })?;
        let len = usize::try_from(len64)
            .map_err(|_| BinError::BadSection { name, why: format!("length {len64} overflows") })?;
        let want = count.checked_mul(std::mem::size_of::<T>()).ok_or_else(|| {
            BinError::BadSection { name, why: format!("element count {count} overflows") }
        })?;
        if len != want {
            return Err(BinError::BadSection {
                name,
                why: format!("length {len} != expected {want} ({count} elements)"),
            });
        }
        if off % SECTION_ALIGN != 0 {
            return Err(BinError::BadSection {
                name,
                why: format!("offset {off} not {SECTION_ALIGN}-byte aligned"),
            });
        }
        if off < self.min_off {
            return Err(BinError::BadSection {
                name,
                why: format!(
                    "offset {off} overlaps the previous section (ends at {})",
                    self.min_off
                ),
            });
        }
        let end = off.checked_add(len).ok_or_else(|| BinError::BadSection {
            name,
            why: "extent overflows".to_string(),
        })?;
        if end > self.bytes.len() {
            return Err(BinError::BadSection {
                name,
                why: format!("extent {off}..{end} exceeds file length {}", self.bytes.len()),
            });
        }
        self.min_off = end;
        // SAFETY: `off..end` is in bounds (checked above); the base
        // pointer is 8-byte aligned (enforced by `load`) and `off` is a
        // multiple of 64, so `base + off` satisfies `align_of::<T>() ≤ 8`;
        // T is Pod, so any byte content is a valid value.
        let ptr = unsafe { self.bytes.as_ptr().add(off) };
        debug_assert_eq!(ptr as usize % std::mem::align_of::<T>(), 0);
        Ok(unsafe { std::slice::from_raw_parts(ptr.cast::<T>(), count) })
    }
}

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_ne_bytes(bytes[at..at + 4].try_into().unwrap())
}

/// Parse and fully validate a binary model over borrowed bytes.
///
/// The base pointer must be 8-byte aligned (mapped files and
/// [`OwnedBin`] buffers are); arbitrary `&[u8]` sources should go
/// through [`OwnedBin::from_bytes`]. On success every structural
/// invariant the unchecked traversal kernels rely on has been
/// re-established — see the module docs for the full checklist.
pub fn load(bytes: &[u8]) -> Result<BinView<'_>, BinError> {
    if bytes.as_ptr() as usize % 8 != 0 {
        return Err(BinError::Unaligned);
    }
    if bytes.len() < HEADER_LEN {
        return Err(BinError::TooShort { need: HEADER_LEN, got: bytes.len() });
    }
    if bytes[..4] != MAGIC {
        return Err(BinError::BadMagic([bytes[0], bytes[1], bytes[2], bytes[3]]));
    }
    let version = read_u32(bytes, 4);
    if version != VERSION {
        return Err(BinError::BadVersion(version));
    }
    let endian = read_u32(bytes, 8);
    if endian != ENDIAN_TAG {
        return Err(BinError::BadEndianness(endian));
    }
    let kind_code = read_u32(bytes, 12);
    let kind = match kind_code {
        0 => BinKind::Rf,
        1 => BinKind::Gbt,
        k => return Err(BinError::BadKind(k)),
    };
    let n_features = read_u32(bytes, 16) as usize;
    let n_classes = read_u32(bytes, 20) as usize;
    let n_trees = read_u32(bytes, 24) as usize;
    let n_nodes = read_u32(bytes, 28) as usize;
    let n_leaves = read_u32(bytes, 32) as usize;
    let order_code = read_u32(bytes, 36);
    let scale_shift = read_u32(bytes, 40);
    let qs_blocks = read_u32(bytes, 44) as usize;
    let qs_fallback = read_u32(bytes, 48) as usize;
    let qs_conds = read_u32(bytes, 52) as usize;
    let qs_payloads = read_u32(bytes, 56) as usize;
    let n_sections = read_u32(bytes, 60) as usize;
    let file_len = u64::from_ne_bytes(bytes[64..72].try_into().unwrap());

    let bad = |why: String| Err(BinError::BadHeader(why));
    if file_len != bytes.len() as u64 {
        return bad(format!("declared file length {file_len} != actual {}", bytes.len()));
    }
    if bytes[72..HEADER_LEN].iter().any(|&b| b != 0) {
        return bad("reserved header bytes are not zero".to_string());
    }
    if n_features == 0 || n_features > MAX_FEATURES {
        return bad(format!("n_features {n_features} outside 1..={MAX_FEATURES}"));
    }
    if n_classes == 0 || n_classes > MAX_CLASSES {
        return bad(format!("n_classes {n_classes} outside 1..={MAX_CLASSES}"));
    }
    if n_trees == 0 || n_trees > MAX_TREES {
        return bad(format!("n_trees {n_trees} outside 1..={MAX_TREES}"));
    }
    if n_nodes < n_trees {
        return bad(format!("n_nodes {n_nodes} < n_trees {n_trees} (every tree has a root)"));
    }
    if n_leaves == 0 || n_leaves > n_nodes {
        return bad(format!("n_leaves {n_leaves} outside 1..=n_nodes ({n_nodes})"));
    }
    let order = match order_code {
        0 => NodeOrder::Depth,
        1 => NodeOrder::Breadth,
        c => return bad(format!("unknown node-order code {c}")),
    };
    match kind {
        BinKind::Rf => {
            if scale_shift != 0 {
                return bad(format!("RF artifacts carry no margin scale (shift {scale_shift})"));
            }
        }
        BinKind::Gbt => {
            if order != NodeOrder::Breadth {
                return bad("GBT artifacts are always breadth-ordered".to_string());
            }
            if scale_shift > MAX_SCALE_SHIFT {
                return bad(format!("margin scale shift {scale_shift} > {MAX_SCALE_SHIFT}"));
            }
        }
    }
    let expected_sections = match kind {
        BinKind::Rf => RF_SECTIONS,
        BinKind::Gbt => GBT_SECTIONS,
    };
    if n_sections != expected_sections {
        return bad(format!(
            "{} artifacts have {expected_sections} sections, header declares {n_sections}",
            kind.name()
        ));
    }
    let table_end = HEADER_LEN + n_sections * 16;
    if bytes.len() < table_end {
        return Err(BinError::TooShort { need: table_end, got: bytes.len() });
    }
    let leaf_rows = n_leaves
        .checked_mul(n_classes)
        .ok_or_else(|| BinError::BadHeader("leaf table size overflows".to_string()))?;

    let mut cur = Cursor { bytes, idx: 0, min_off: table_end };
    let body = match kind {
        BinKind::Rf => Body::Rf(RfSections {
            tree_offsets: cur.take("tree_offsets", n_trees + 1)?,
            tree_depths: cur.take("tree_depths", n_trees)?,
            feature: cur.take("feature", n_nodes)?,
            thresh_f32: cur.take("thresh_f32", n_nodes)?,
            thresh_ord: cur.take("thresh_ord", n_nodes)?,
            left: cur.take("left", n_nodes)?,
            right: cur.take("right", n_nodes)?,
            leaf_f32: cur.take("leaf_f32", leaf_rows)?,
            leaf_u32: cur.take("leaf_u32", leaf_rows)?,
            nodes_f32: cur.take("nodes_f32", n_nodes)?,
            nodes_ord: cur.take("nodes_ord", n_nodes)?,
            soa_tw_ord: cur.take("soa_tw_ord", n_nodes)?,
            soa_tw_f32: cur.take("soa_tw_f32", n_nodes)?,
            soa_ffl: cur.take("soa_ffl", n_nodes)?,
        }),
        BinKind::Gbt => Body::Gbt(GbtSections {
            tree_offsets: cur.take("tree_offsets", n_trees + 1)?,
            tree_depths: cur.take("tree_depths", n_trees)?,
            nodes: cur.take("nodes", n_nodes)?,
            soa_tw: cur.take("soa_tw", n_nodes)?,
            soa_ffl: cur.take("soa_ffl", n_nodes)?,
            leaf_q: cur.take("leaf_q", leaf_rows)?,
            base_q: cur.take("base_q", n_classes)?,
        }),
    };

    // QS meta first — the remaining QS section lengths derive from it.
    let meta = cur.take::<u32>("qs_block_meta", qs_blocks * 3)?;
    let mut sum_trees = 0usize;
    let mut sum_conds = 0usize;
    let mut sum_payloads = 0usize;
    for m in meta.chunks_exact(3) {
        let add = |acc: usize, v: u32, what: &str| {
            acc.checked_add(v as usize)
                .ok_or_else(|| BinError::BadHeader(format!("QS {what} total overflows")))
        };
        sum_trees = add(sum_trees, m[0], "tree")?;
        sum_conds = add(sum_conds, m[1], "condition")?;
        sum_payloads = add(sum_payloads, m[2], "payload")?;
    }
    if sum_conds != qs_conds {
        return bad(format!("QS condition total {sum_conds} != header {qs_conds}"));
    }
    if sum_payloads != qs_payloads {
        return bad(format!("QS payload total {sum_payloads} != header {qs_payloads}"));
    }
    let fo_count = qs_blocks
        .checked_mul(n_features + 1)
        .ok_or_else(|| BinError::BadHeader("QS feature-offset table size overflows".to_string()))?;
    let qs = QsSections {
        meta,
        tree_ids: cur.take("qs_tree_ids", sum_trees)?,
        init: cur.take("qs_init", sum_trees)?,
        feature_offsets: cur.take("qs_feature_offsets", fo_count)?,
        thresh_ord: cur.take("qs_thresh_ord", qs_conds)?,
        thresh_f32: cur.take("qs_thresh_f32", qs_conds)?,
        tree_of: cur.take("qs_tree_of", qs_conds)?,
        masks: cur.take("qs_masks", qs_conds)?,
        leaf_offsets: cur.take("qs_leaf_offsets", sum_trees + qs_blocks)?,
        payloads: cur.take("qs_payloads", qs_payloads)?,
        fallback: cur.take("qs_fallback", qs_fallback)?,
    };

    let view = BinView {
        kind,
        n_features,
        n_classes,
        n_trees,
        n_nodes,
        n_leaves,
        order,
        scale_shift,
        resident_bytes: bytes.len(),
        body,
        qs,
    };
    view.validate()?;
    Ok(view)
}

// ---------------------------------------------------------------------------
// Semantic validation

/// Shared per-tree packed-node validation: canonical leaf/branch
/// encoding, child adjacency, acyclicity (children strictly after their
/// parent), payload bounds, and the exact depth the branchless kernel
/// trusts as its fixed trip count.
fn validate_packed(
    nodes: &[Node8],
    tree_offsets: &[u32],
    tree_depths: &[u32],
    n_features: usize,
    n_leaves: usize,
) -> Result<(), BinError> {
    let err = |why: String| Err(BinError::Malformed(why));
    for (t, w) in tree_offsets.windows(2).enumerate() {
        let lo = w[0] as usize;
        let hi = w[1] as usize;
        let n = hi - lo;
        // depth[i] = longest path below local node i, filled in reverse
        // index order — children always sit at larger local indices
        // (validated below), so both are done before their parent.
        let mut depth = vec![0u32; n];
        for i in (0..n).rev() {
            let node = nodes[lo + i];
            if node.ff & LEAF_BIT != 0 {
                if node.ff != LEAF_BIT {
                    return err(format!(
                        "tree {t} node {i}: leaf carries feature bits (ff {:#06x})",
                        node.ff
                    ));
                }
                if node.left as usize != i {
                    return err(format!(
                        "tree {t} node {i}: leaf self-loop points at {}",
                        node.left
                    ));
                }
                if node.tw as usize >= n_leaves {
                    return err(format!(
                        "tree {t} node {i}: leaf payload {} >= {n_leaves}",
                        node.tw
                    ));
                }
            } else {
                if (node.ff as usize) >= n_features {
                    return err(format!("tree {t} node {i}: feature {} >= {n_features}", node.ff));
                }
                let l = node.left as usize;
                if l <= i {
                    return err(format!("tree {t} node {i}: left child {l} not after its parent"));
                }
                if l + 1 >= n {
                    return err(format!(
                        "tree {t} node {i}: children {l},{} outside tree of {n} nodes",
                        l + 1
                    ));
                }
                depth[i] = 1 + depth[l].max(depth[l + 1]);
            }
        }
        if depth[0] != tree_depths[t] {
            return err(format!(
                "tree {t}: declared depth {} != computed {}",
                tree_depths[t], depth[0]
            ));
        }
    }
    Ok(())
}

/// Tree-offset table: starts at zero, strictly increasing (no empty
/// trees), per-tree size within the u16-indexed packing limit, ends at
/// the node count.
fn validate_tree_offsets(tree_offsets: &[u32], n_nodes: usize) -> Result<(), BinError> {
    let err = |why: String| Err(BinError::Malformed(why));
    if tree_offsets[0] != 0 {
        return err(format!("tree_offsets[0] is {}, not 0", tree_offsets[0]));
    }
    for (t, w) in tree_offsets.windows(2).enumerate() {
        let lo = w[0] as usize;
        let hi = w[1] as usize;
        if hi <= lo {
            return err(format!("tree {t} is empty or offsets regress ({lo}..{hi})"));
        }
        if hi - lo > MAX_TREE_NODES {
            return err(format!("tree {t} has {} nodes > {MAX_TREE_NODES}", hi - lo));
        }
    }
    let last = tree_offsets[tree_offsets.len() - 1] as usize;
    if last != n_nodes {
        return err(format!("tree_offsets end at {last}, node count is {n_nodes}"));
    }
    Ok(())
}

impl BinView<'_> {
    fn validate(&self) -> Result<(), BinError> {
        match &self.body {
            Body::Rf(rf) => self.validate_rf(rf)?,
            Body::Gbt(g) => self.validate_gbt(g)?,
        }
        self.validate_qs()
    }

    fn validate_rf(&self, rf: &RfSections<'_>) -> Result<(), BinError> {
        validate_tree_offsets(rf.tree_offsets, self.n_nodes)?;
        validate_packed(
            rf.nodes_ord,
            rf.tree_offsets,
            rf.tree_depths,
            self.n_features,
            self.n_leaves,
        )?;
        let err = |why: String| Err(BinError::Malformed(why));
        // The two packed domains and the five SoA mirrors must agree
        // node for node — the SIMD gathers and the scalar walkers read
        // different copies of the same tree and must route identically.
        for (g, &no) in rf.nodes_ord.iter().enumerate() {
            let nf = rf.nodes_f32[g];
            if nf.ff != no.ff || nf.left != no.left {
                return err(format!("node {g}: ord/f32 packs disagree on ff/left"));
            }
            if rf.soa_tw_ord[g] != no.tw {
                return err(format!("node {g}: soa_tw_ord mirror diverges"));
            }
            if rf.soa_tw_f32[g] != nf.tw {
                return err(format!("node {g}: soa_tw_f32 mirror diverges"));
            }
            if rf.soa_ffl[g] != no.ffl_word() {
                return err(format!("node {g}: soa_ffl mirror diverges"));
            }
            if no.ff == LEAF_BIT {
                if nf.tw != no.tw {
                    return err(format!("node {g}: leaf payload differs across domains"));
                }
                if rf.feature[g] != LEAF
                    || rf.thresh_ord[g] != 0
                    || rf.thresh_f32[g].to_bits() != 0
                    || rf.left[g] != no.tw
                    || rf.right[g] != 0
                {
                    return err(format!("node {g}: SoA leaf row diverges from packed leaf"));
                }
            } else {
                if rf.feature[g] != no.ff as u32 {
                    return err(format!("node {g}: SoA feature column diverges"));
                }
                if rf.thresh_ord[g] != no.tw || rf.thresh_f32[g].to_bits() != nf.tw {
                    return err(format!("node {g}: SoA threshold columns diverge"));
                }
                if rf.thresh_ord[g] != ordered_u32(rf.thresh_f32[g]) {
                    return err(format!(
                        "node {g}: ordered threshold is not the order-preserving map of the f32 threshold"
                    ));
                }
                if rf.left[g] != no.left as u32 || rf.right[g] != no.left as u32 + 1 {
                    return err(format!("node {g}: SoA child columns diverge"));
                }
            }
        }
        Ok(())
    }

    fn validate_gbt(&self, g: &GbtSections<'_>) -> Result<(), BinError> {
        validate_tree_offsets(g.tree_offsets, self.n_nodes)?;
        validate_packed(g.nodes, g.tree_offsets, g.tree_depths, self.n_features, self.n_leaves)?;
        let err = |why: String| Err(BinError::Malformed(why));
        for (i, node) in g.nodes.iter().enumerate() {
            if g.soa_tw[i] != node.tw {
                return err(format!("node {i}: soa_tw mirror diverges"));
            }
            if g.soa_ffl[i] != node.ffl_word() {
                return err(format!("node {i}: soa_ffl mirror diverges"));
            }
        }
        Ok(())
    }

    /// QuickScorer plan validation. The scan kernels index payloads as
    /// `leaf_offsets[tree] + trailing_zeros(bitvector)` without bounds
    /// checks, so beyond shape checks this establishes the invariant
    /// that keeps every final bitvector nonzero: each tree's in-order
    /// last leaf (bit `k-1`) is in no condition's cleared left subtree,
    /// so every mask — and `init` — must keep that bit set.
    fn validate_qs(&self) -> Result<(), BinError> {
        let q = &self.qs;
        let err = |why: String| Err(BinError::Malformed(why));
        let mut seen = vec![false; self.n_trees];
        let mut claim = |id: u32, what: &str| -> Result<(), BinError> {
            let i = id as usize;
            if i >= self.n_trees {
                return Err(BinError::Malformed(format!(
                    "QS {what} names tree {i} of {}",
                    self.n_trees
                )));
            }
            if seen[i] {
                return Err(BinError::Malformed(format!("QS assigns tree {i} twice")));
            }
            seen[i] = true;
            Ok(())
        };
        let (mut t0, mut c0, mut p0, mut f0, mut l0) = (0usize, 0usize, 0usize, 0usize, 0usize);
        for (b, m) in q.meta.chunks_exact(3).enumerate() {
            let bt = m[0] as usize;
            let bc = m[1] as usize;
            let bp = m[2] as usize;
            if bt == 0 {
                return err(format!("QS block {b} holds no trees"));
            }
            if bt > u16::MAX as usize + 1 {
                return err(format!("QS block {b} holds {bt} trees (> u16 range)"));
            }
            let tree_ids = &q.tree_ids[t0..t0 + bt];
            let init = &q.init[t0..t0 + bt];
            let fo = &q.feature_offsets[f0..f0 + self.n_features + 1];
            let tree_of = &q.tree_of[c0..c0 + bc];
            let masks = &q.masks[c0..c0 + bc];
            let thresh_ord = &q.thresh_ord[c0..c0 + bc];
            let lofs = &q.leaf_offsets[l0..l0 + bt + 1];
            let payloads = &q.payloads[p0..p0 + bp];
            for &id in tree_ids {
                claim(id, "block")?;
            }
            // Leaf ranges: k leaves per tree, 1..=64, offsets exact.
            if lofs[0] != 0 {
                return err(format!("QS block {b}: leaf_offsets[0] is {}, not 0", lofs[0]));
            }
            let mut leaves = vec![0usize; bt];
            for (j, lw) in lofs.windows(2).enumerate() {
                let a = lw[0] as usize;
                let z = lw[1] as usize;
                if z <= a {
                    return err(format!("QS block {b} tree {j}: empty/regressing leaf range"));
                }
                let k = z - a;
                if k > QS_MAX_LEAVES {
                    return err(format!("QS block {b} tree {j}: {k} leaves > {QS_MAX_LEAVES}"));
                }
                leaves[j] = k;
                let want_init = if k == 64 { u64::MAX } else { (1u64 << k) - 1 };
                if init[j] != want_init {
                    return err(format!(
                        "QS block {b} tree {j}: init {:#018x} != {want_init:#018x} for {k} leaves",
                        init[j]
                    ));
                }
            }
            if lofs[bt] as usize != bp {
                return err(format!(
                    "QS block {b}: leaf_offsets end at {}, payload count is {bp}",
                    lofs[bt]
                ));
            }
            for &p in payloads {
                if p as usize >= self.n_leaves {
                    return err(format!("QS block {b}: payload row {p} >= {}", self.n_leaves));
                }
            }
            // Condition streams: bucketed by feature, sorted ascending,
            // each naming an in-block tree and keeping that tree's last
            // in-order leaf bit set.
            if fo[0] != 0 {
                return err(format!("QS block {b}: feature_offsets[0] is {}, not 0", fo[0]));
            }
            for (f, fw) in fo.windows(2).enumerate() {
                let (s, e) = (fw[0] as usize, fw[1] as usize);
                if e < s || e > bc {
                    return err(format!("QS block {b} feature {f}: bucket {s}..{e} invalid"));
                }
                for c in s..e {
                    if c > s && thresh_ord[c] < thresh_ord[c - 1] {
                        return err(format!("QS block {b} feature {f}: conditions not sorted at {c}"));
                    }
                    let tl = tree_of[c] as usize;
                    if tl >= bt {
                        return err(format!("QS block {b} condition {c}: tree {tl} of {bt}"));
                    }
                    let last_bit = 1u64 << (leaves[tl] - 1);
                    if masks[c] & last_bit == 0 {
                        return err(format!(
                            "QS block {b} condition {c}: mask clears its tree's last leaf bit"
                        ));
                    }
                }
            }
            if fo[self.n_features] as usize != bc {
                return err(format!(
                    "QS block {b}: feature_offsets end at {}, condition count is {bc}",
                    fo[self.n_features]
                ));
            }
            t0 += bt;
            c0 += bc;
            p0 += bp;
            f0 += self.n_features + 1;
            l0 += bt + 1;
        }
        for &id in q.fallback {
            claim(id, "fallback")?;
        }
        if seen.iter().any(|&s| !s) {
            return err("QS plan does not cover every tree".to_string());
        }
        Ok(())
    }

    // -- public accessors ---------------------------------------------------

    /// Model kind stored in the artifact.
    pub fn kind(&self) -> BinKind {
        self.kind
    }

    /// Feature columns the model consumes.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Classes the model predicts.
    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Trees in the ensemble.
    pub fn n_trees(&self) -> usize {
        self.n_trees
    }

    /// Total packed nodes.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Leaf payload rows.
    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    /// Node layout the forest was compiled with.
    pub fn order(&self) -> NodeOrder {
        self.order
    }

    /// Total artifact size in bytes — what a resident model costs, the
    /// figure the registry's per-model memory accounting reports.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    fn qs_plan(&self) -> QsPlan {
        let q = &self.qs;
        let mut blocks = Vec::with_capacity(q.meta.len() / 3);
        let (mut t0, mut c0, mut p0, mut f0, mut l0) = (0usize, 0usize, 0usize, 0usize, 0usize);
        for m in q.meta.chunks_exact(3) {
            let bt = m[0] as usize;
            let bc = m[1] as usize;
            let bp = m[2] as usize;
            blocks.push(QsBlock {
                n_trees: bt,
                tree_ids: q.tree_ids[t0..t0 + bt].to_vec(),
                init: q.init[t0..t0 + bt].to_vec(),
                feature_offsets: q.feature_offsets[f0..f0 + self.n_features + 1].to_vec(),
                thresh_ord: q.thresh_ord[c0..c0 + bc].to_vec(),
                thresh_f32: q.thresh_f32[c0..c0 + bc].to_vec(),
                tree_of: q.tree_of[c0..c0 + bc].to_vec(),
                masks: q.masks[c0..c0 + bc].to_vec(),
                leaf_offsets: q.leaf_offsets[l0..l0 + bt + 1].to_vec(),
                leaf_payloads: q.payloads[p0..p0 + bp].to_vec(),
            });
            t0 += bt;
            c0 += bc;
            p0 += bp;
            f0 += self.n_features + 1;
            l0 += bt + 1;
        }
        QsPlan {
            n_trees: self.n_trees,
            n_features: self.n_features,
            blocks,
            fallback: q.fallback.to_vec(),
        }
    }

    /// Materialize a random-forest [`CompiledForest`] — bulk copies of
    /// the validated slices, no per-node rebuild.
    pub fn to_forest(&self) -> Result<CompiledForest, BinError> {
        let rf = match &self.body {
            Body::Rf(rf) => rf,
            Body::Gbt(_) => return Err(BinError::KindMismatch { expected: "rf", got: "gbt" }),
        };
        Ok(CompiledForest {
            n_features: self.n_features,
            n_classes: self.n_classes,
            n_trees: self.n_trees,
            tree_offsets: rf.tree_offsets.to_vec(),
            tree_depths: rf.tree_depths.to_vec(),
            feature: rf.feature.to_vec(),
            thresh_f32: rf.thresh_f32.to_vec(),
            thresh_ord: rf.thresh_ord.to_vec(),
            left: rf.left.to_vec(),
            right: rf.right.to_vec(),
            leaf_f32: rf.leaf_f32.to_vec(),
            leaf_u32: rf.leaf_u32.to_vec(),
            nodes_f32: rf.nodes_f32.to_vec(),
            nodes_ord: rf.nodes_ord.to_vec(),
            soa_tw_ord: rf.soa_tw_ord.to_vec(),
            soa_tw_f32: rf.soa_tw_f32.to_vec(),
            soa_ffl: rf.soa_ffl.to_vec(),
            order: self.order,
            qs: self.qs_plan(),
        })
    }

    /// Materialize a [`GbtIntEngine`] with default execution knobs —
    /// bulk copies of the validated slices, no per-node rebuild.
    pub fn to_gbt(&self) -> Result<GbtIntEngine, BinError> {
        let g = match &self.body {
            Body::Gbt(g) => g,
            Body::Rf(_) => return Err(BinError::KindMismatch { expected: "gbt", got: "rf" }),
        };
        Ok(GbtIntEngine::from_parts(GbtEngineParts {
            n_features: self.n_features,
            n_classes: self.n_classes,
            scale: MarginScale { shift: self.scale_shift },
            tree_offsets: g.tree_offsets.to_vec(),
            tree_depths: g.tree_depths.to_vec(),
            nodes: g.nodes.to_vec(),
            soa_tw: g.soa_tw.to_vec(),
            soa_ffl: g.soa_ffl.to_vec(),
            leaf_q: g.leaf_q.to_vec(),
            base_q: g.base_q.to_vec(),
            qs: self.qs_plan(),
        }))
    }
}

// ---------------------------------------------------------------------------
// Owned fallback for unaligned sources

/// An owned, 8-byte-aligned copy of artifact bytes — the fallback when
/// the source (a `Vec<u8>` file read, a network buffer) promises no
/// base alignment. The copy is backed by `u64` words, so
/// [`Self::view`] always passes [`load`]'s alignment gate.
pub struct OwnedBin {
    words: Vec<u64>,
    len: usize,
}

impl OwnedBin {
    /// Copy arbitrary bytes into an aligned buffer.
    pub fn from_bytes(bytes: &[u8]) -> OwnedBin {
        let mut words = Vec::with_capacity(bytes.len().div_ceil(8));
        for chunk in bytes.chunks(8) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            words.push(u64::from_ne_bytes(b));
        }
        OwnedBin { words, len: bytes.len() }
    }

    /// The artifact bytes (8-byte-aligned base).
    pub fn bytes(&self) -> &[u8] {
        &bytes_of(&self.words)[..self.len]
    }

    /// Parse and validate — see [`load`].
    pub fn view(&self) -> Result<BinView<'_>, BinError> {
        load(self.bytes())
    }
}

// ---------------------------------------------------------------------------
// mmap(2)-backed zero-copy load path (unix)

/// Minimal FFI surface over the `mmap`/`munmap` symbols libc already
/// links for std — no new crate dependency. Only what a read-only
/// private file mapping needs.
#[cfg(unix)]
mod mm {
    /// Pages are readable.
    pub const PROT_READ: i32 = 1;
    /// Private (copy-on-write) mapping; we never write, so it simply
    /// shares page-cache pages.
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        /// `off_t` is declared `isize`: pointer-width on every LP64
        /// unix target rustc supports, and the only offset ever passed
        /// here is 0.
        pub fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: isize,
        ) -> *mut core::ffi::c_void;
        pub fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    /// `MAP_FAILED` is `(void *)-1`.
    pub fn map_failed() -> *mut core::ffi::c_void {
        usize::MAX as *mut core::ffi::c_void
    }
}

/// A read-only `mmap(2)` view of an artifact file — the zero-copy load
/// path: no heap copy of the artifact is made and resident memory is
/// O(validation), because file pages fault in on demand from the page
/// cache. The mapping base is page-aligned (≥ 4096), so [`load`]'s
/// 8-byte base-alignment gate holds by construction and every
/// 64-byte-aligned section casts cleanly.
#[cfg(unix)]
pub struct MappedBin {
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE — immutable shared
// bytes for the mapping's lifetime, the same sharing contract as a
// `&'static [u8]`.
#[cfg(unix)]
unsafe impl Send for MappedBin {}
#[cfg(unix)]
unsafe impl Sync for MappedBin {}

#[cfg(unix)]
impl MappedBin {
    /// Map `path` read-only. Fails with the underlying I/O error when
    /// the file cannot be opened, sized, or mapped — callers that want
    /// the portable fallback go through [`FileBin::open`].
    pub fn open(path: &std::path::Path) -> std::io::Result<MappedBin> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = usize::try_from(file.metadata()?.len()).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "file larger than address space")
        })?;
        if len == 0 {
            // mmap(2) rejects zero-length mappings; an empty file is an
            // empty (invalid) artifact, represented without a mapping.
            return Ok(MappedBin { ptr: std::ptr::NonNull::dangling(), len: 0 });
        }
        // SAFETY: plain mmap call over a live fd; MAP_PRIVATE file
        // mappings keep the underlying file referenced after the fd
        // closes, so the mapping outlives `file`.
        let p = unsafe {
            mm::mmap(std::ptr::null_mut(), len, mm::PROT_READ, mm::MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if p.is_null() || p == mm::map_failed() {
            return Err(std::io::Error::last_os_error());
        }
        let ptr = std::ptr::NonNull::new(p.cast::<u8>())
            .ok_or_else(std::io::Error::last_os_error)?;
        Ok(MappedBin { ptr, len })
    }

    /// The mapped artifact bytes (page-aligned base; empty for an
    /// empty file).
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len describe the live mapping, immutable
        // (PROT_READ) for the lifetime of `self`.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }

    /// Parse and validate — see [`load`].
    pub fn view(&self) -> Result<BinView<'_>, BinError> {
        load(self.bytes())
    }
}

#[cfg(unix)]
impl Drop for MappedBin {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: exactly the mapping created in `open`, unmapped
            // once. munmap can only fail on a bad range, which this
            // is not; the result is ignored like std's own unmaps.
            unsafe { mm::munmap(self.ptr.as_ptr().cast(), self.len) };
        }
    }
}

/// A loaded artifact file: `mmap(2)`-backed where the platform allows
/// it, an [`OwnedBin`] heap copy otherwise. The fleet loader and the
/// CLI load through this one type, so the preferred path is chosen in
/// exactly one place.
pub enum FileBin {
    /// Zero-copy page-aligned file mapping.
    #[cfg(unix)]
    Mapped(MappedBin),
    /// Aligned heap copy of the file bytes (portable / fallback path).
    Owned(OwnedBin),
}

impl FileBin {
    /// Open `path`, preferring the `mmap(2)` path on unix. A refused
    /// mapping on an existing file (exotic filesystem, seccomp-filtered
    /// syscall) falls back to a buffered read + aligned copy — loudly,
    /// because the load still succeeds but without the resident-memory
    /// win. A missing or unreadable file is an error either way.
    pub fn open(path: &std::path::Path) -> std::io::Result<FileBin> {
        #[cfg(unix)]
        {
            match MappedBin::open(path) {
                Ok(m) => return Ok(FileBin::Mapped(m)),
                Err(e) => {
                    if !path.is_file() {
                        return Err(e);
                    }
                    eprintln!(
                        "intreeger: mmap of {} failed ({e}); falling back to an owned copy",
                        path.display()
                    );
                }
            }
        }
        let bytes = std::fs::read(path)?;
        Ok(FileBin::Owned(OwnedBin::from_bytes(&bytes)))
    }

    /// The artifact bytes (8-byte-aligned base on both variants).
    pub fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            FileBin::Mapped(m) => m.bytes(),
            FileBin::Owned(o) => o.bytes(),
        }
    }

    /// Parse and validate — see [`load`].
    pub fn view(&self) -> Result<BinView<'_>, BinError> {
        load(self.bytes())
    }

    /// Which load path backs this artifact (`"mmap"` / `"owned-copy"`)
    /// — surfaced in load logs and the E14 bench rows.
    pub fn source(&self) -> &'static str {
        match self {
            #[cfg(unix)]
            FileBin::Mapped(_) => "mmap",
            FileBin::Owned(_) => "owned-copy",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{train_gbt, ForestParams, GbtParams, RandomForest};

    fn rf_model() -> Model {
        let ds = shuttle_like(400, 9);
        RandomForest::train(&ds, &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() }, 9)
    }

    fn gbt_model() -> Model {
        let ds = shuttle_like(300, 11);
        train_gbt(&ds, &GbtParams { n_rounds: 3, max_depth: 3, ..Default::default() }, 11)
    }

    #[test]
    fn rf_round_trip_and_byte_stability() {
        let f = CompiledForest::compile(&rf_model());
        let bytes = write_forest(&f);
        let owned = OwnedBin::from_bytes(&bytes);
        let view = owned.view().expect("own artifact must load");
        assert_eq!(view.kind(), BinKind::Rf);
        assert_eq!(view.n_features(), f.n_features);
        assert_eq!(view.n_trees(), f.n_trees);
        assert_eq!(view.resident_bytes(), bytes.len());
        let f2 = view.to_forest().expect("RF artifact materializes as a forest");
        assert_eq!(f2.nodes_ord, f.nodes_ord);
        assert_eq!(
            f2.thresh_f32.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            f.thresh_f32.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(f2.leaf_u32, f.leaf_u32);
        assert_eq!(f2.qs.blocks.len(), f.qs.blocks.len());
        // write → load → write is byte-stable.
        assert_eq!(write_forest(&f2), bytes);
        // GBT materialization of an RF artifact is a typed mismatch.
        assert_eq!(
            view.to_gbt().err(),
            Some(BinError::KindMismatch { expected: "gbt", got: "rf" })
        );
    }

    #[test]
    fn gbt_round_trip_and_byte_stability() {
        let e = GbtIntEngine::compile(&gbt_model());
        let bytes = write_gbt(&e);
        let owned = OwnedBin::from_bytes(&bytes);
        let view = owned.view().expect("own artifact must load");
        assert_eq!(view.kind(), BinKind::Gbt);
        let e2 = view.to_gbt().expect("GBT artifact materializes as an engine");
        assert_eq!(e2.scale().shift, e.scale().shift);
        assert_eq!(write_gbt(&e2), bytes);
        assert_eq!(
            view.to_forest().err(),
            Some(BinError::KindMismatch { expected: "rf", got: "gbt" })
        );
    }

    #[test]
    fn unaligned_base_is_refused_and_owned_copy_recovers() {
        let bytes = write_model(&rf_model());
        // Build a deliberately misaligned view: copy into an 8-aligned
        // u64 buffer at byte offset 1.
        let mut backing = vec![0u64; bytes.len() / 8 + 2];
        assert_eq!(backing.as_ptr() as usize % 8, 0);
        {
            // SAFETY: u64 backing reinterpreted as its full byte span.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(backing.as_mut_ptr().cast::<u8>(), backing.len() * 8)
            };
            dst[1..1 + bytes.len()].copy_from_slice(&bytes);
        }
        // SAFETY: offset 1 stays within the backing allocation.
        let shifted = unsafe {
            std::slice::from_raw_parts((backing.as_ptr() as *const u8).add(1), bytes.len())
        };
        assert_eq!(load(shifted).err(), Some(BinError::Unaligned));
        // The owned fallback re-aligns the same bytes.
        assert!(OwnedBin::from_bytes(shifted).view().is_ok());
    }

    #[test]
    fn short_and_foreign_inputs_are_typed_errors() {
        assert_eq!(
            OwnedBin::from_bytes(&[]).view().err(),
            Some(BinError::TooShort { need: HEADER_LEN, got: 0 })
        );
        let json = vec![b'{'; 200];
        assert!(matches!(OwnedBin::from_bytes(&json).view().err(), Some(BinError::BadMagic(_))));
        assert!(is_binary(&write_model(&rf_model())));
        assert!(!is_binary(&json));
    }

    #[test]
    fn header_field_mutations_are_typed_errors() {
        let bytes = write_model(&rf_model());
        let patch = |at: usize, v: u32| {
            let mut b = bytes.clone();
            b[at..at + 4].copy_from_slice(&v.to_ne_bytes());
            OwnedBin::from_bytes(&b).view().err().expect("mutation must be rejected")
        };
        assert_eq!(patch(4, 2), BinError::BadVersion(2));
        assert_eq!(
            patch(8, ENDIAN_TAG.swap_bytes()),
            BinError::BadEndianness(ENDIAN_TAG.swap_bytes())
        );
        assert_eq!(patch(12, 7), BinError::BadKind(7));
        // n_features 0 / n_trees over the cap die in the header gate; a
        // corrupted n_nodes survives it and dies on the first section
        // whose length no longer matches.
        assert!(matches!(patch(16, 0), BinError::BadHeader(_)));
        assert!(matches!(patch(24, u32::MAX), BinError::BadHeader(_)));
        assert!(matches!(patch(28, u32::MAX), BinError::BadSection { .. }));
        assert!(matches!(patch(60, 3), BinError::BadHeader(_)));
    }

    #[test]
    fn file_bin_round_trip_prefers_mmap_and_matches_owned() {
        let bytes = write_model(&rf_model());
        let dir = std::env::temp_dir()
            .join(format!("intreeger_binfmt_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rf.intb");
        std::fs::write(&path, &bytes).unwrap();

        let fb = FileBin::open(&path).expect("open artifact");
        #[cfg(unix)]
        assert_eq!(fb.source(), "mmap", "unix loads must take the zero-copy path");
        assert_eq!(fb.bytes().as_ptr() as usize % 8, 0, "base alignment gate");
        assert_eq!(fb.bytes(), &bytes[..], "mapped bytes are the file bytes");

        let mapped_forest =
            fb.view().expect("mapped view validates").to_forest().expect("forest");
        let owned_forest = OwnedBin::from_bytes(&bytes)
            .view()
            .expect("owned view validates")
            .to_forest()
            .expect("forest");
        assert_eq!(mapped_forest.nodes_ord, owned_forest.nodes_ord);
        assert_eq!(mapped_forest.leaf_u32, owned_forest.leaf_u32);
    }

    #[cfg(unix)]
    #[test]
    fn mapped_bin_page_alignment_and_empty_file() {
        let dir = std::env::temp_dir()
            .join(format!("intreeger_binfmt_mmap_edge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        let empty = dir.join("empty.intb");
        std::fs::write(&empty, b"").unwrap();
        let m = MappedBin::open(&empty).expect("empty file maps as empty bytes");
        assert!(m.bytes().is_empty());
        assert_eq!(m.view().err(), Some(BinError::TooShort { need: HEADER_LEN, got: 0 }));

        let real = dir.join("rf.intb");
        std::fs::write(&real, write_model(&rf_model())).unwrap();
        let m = MappedBin::open(&real).expect("map artifact");
        assert_eq!(m.bytes().as_ptr() as usize % 4096, 0, "mmap base is page-aligned");
        assert!(m.view().is_ok());
    }

    #[test]
    fn file_bin_missing_file_is_an_error_and_owned_fallback_loads() {
        let missing = std::env::temp_dir()
            .join(format!("intreeger_binfmt_missing_{}", std::process::id()))
            .join("nope.intb");
        assert!(FileBin::open(&missing).is_err(), "missing files never fall back");

        let bytes = write_model(&rf_model());
        let fb = FileBin::Owned(OwnedBin::from_bytes(&bytes));
        assert_eq!(fb.source(), "owned-copy");
        assert!(fb.view().is_ok(), "the portable fallback path stays exercised");
    }
}
