//! Model packing: IR model → padded forest tensors matching an artifact
//! tier's static shapes (the rust half of the contract whose python half
//! is `compile/kernels/ref.py`'s tensor encoding).
//!
//! * thresholds are order-preserved (FlInt) u32;
//! * leaves carry `2^32/n_trees`-scaled fixed-point probabilities
//!   ([`crate::quant::prob_to_fixed`]) and self-loop their child indices;
//! * padding nodes/trees are zero-filled self-loops (semantically inert —
//!   property-tested on the python side and re-checked here).

use super::manifest::{Manifest, Tier};
use crate::flint::ordered_u32;
use crate::ir::{Model, ModelKind, Node};
use crate::quant::prob_to_fixed;

/// Padded tensors for one model in one tier (row-major).
#[derive(Clone, Debug)]
pub struct ForestPack {
    /// Name of the tier these tensors were padded for.
    pub tier_name: String,
    /// i32[T, N]
    pub feat: Vec<i32>,
    /// u32[T, N]
    pub thresh: Vec<u32>,
    /// i32[T, N]
    pub left: Vec<i32>,
    /// i32[T, N]
    pub right: Vec<i32>,
    /// u32[T, N, C]
    pub leaf_val: Vec<u32>,
    /// Padded tree count `T`.
    pub trees: usize,
    /// Padded nodes per tree `N`.
    pub nodes: usize,
    /// Padded class count `C`.
    pub classes: usize,
    /// Padded feature count.
    pub features: usize,
    /// Batch rows the tier executes per call.
    pub batch: usize,
    /// The model's true class count (≤ tier classes).
    pub model_classes: usize,
}

impl ForestPack {
    /// Pack `model` into `tier`'s shapes.
    pub fn pack(model: &Model, tier: &Tier) -> anyhow::Result<ForestPack> {
        anyhow::ensure!(model.kind == ModelKind::RandomForest, "XLA path serves RF models");
        anyhow::ensure!(Manifest::fits(model, tier), "model does not fit tier {}", tier.name);
        let (t, n, c) = (tier.trees, tier.nodes, tier.classes);
        let mut pack = ForestPack {
            tier_name: tier.name.clone(),
            feat: vec![0; t * n],
            thresh: vec![0; t * n],
            // Default: every node self-loops (inert padding).
            left: (0..t * n).map(|i| (i % n) as i32).collect(),
            right: (0..t * n).map(|i| (i % n) as i32).collect(),
            leaf_val: vec![0; t * n * c],
            trees: t,
            nodes: n,
            classes: c,
            features: tier.features,
            batch: tier.batch,
            model_classes: model.n_classes,
        };
        let n_trees = model.trees.len();
        for (ti, tree) in model.trees.iter().enumerate() {
            for (ni, node) in tree.nodes.iter().enumerate() {
                let idx = ti * n + ni;
                match node {
                    Node::Branch { feature, threshold, left, right } => {
                        pack.feat[idx] = *feature as i32;
                        pack.thresh[idx] = ordered_u32(*threshold);
                        pack.left[idx] = *left as i32;
                        pack.right[idx] = *right as i32;
                    }
                    Node::Leaf { values } => {
                        // self-loop already set
                        for (ci, &p) in values.iter().enumerate() {
                            pack.leaf_val[idx * c + ci] = prob_to_fixed(p, n_trees);
                        }
                    }
                }
            }
        }
        Ok(pack)
    }

    /// Transform and pad a batch of float rows into the tier's
    /// `u32[B, F]` input layout. `rows` is row-major with the *model's*
    /// feature count; the result is padded to the tier's batch/features.
    /// Returns (tensor, rows_used).
    pub fn pack_input(&self, rows: &[f32], model_features: usize) -> (Vec<u32>, usize) {
        assert_eq!(rows.len() % model_features, 0);
        let n_rows = rows.len() / model_features;
        assert!(n_rows <= self.batch, "batch overflow: {n_rows} > {}", self.batch);
        let mut x = vec![0u32; self.batch * self.features];
        for r in 0..n_rows {
            for f in 0..model_features {
                x[r * self.features + f] = ordered_u32(rows[r * model_features + f]);
            }
        }
        (x, n_rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    fn tier() -> Tier {
        Tier {
            name: "quick".into(),
            file: "forest_quick.hlo.txt".into(),
            batch: 64,
            features: 8,
            trees: 16,
            nodes: 63,
            classes: 8,
            depth: 6,
            use_pallas: true,
        }
    }

    fn model() -> Model {
        let ds = shuttle_like(800, 90);
        RandomForest::train(&ds, &ForestParams { n_trees: 5, max_depth: 5, ..Default::default() }, 3)
    }

    #[test]
    fn pack_shapes() {
        let m = model();
        let p = ForestPack::pack(&m, &tier()).unwrap();
        assert_eq!(p.feat.len(), 16 * 63);
        assert_eq!(p.leaf_val.len(), 16 * 63 * 8);
        // padding trees: all nodes self-loop with zero leaves
        let t_pad = 10; // beyond the 5 model trees
        for ni in 0..63 {
            let idx = t_pad * 63 + ni;
            assert_eq!(p.left[idx], ni as i32);
            assert_eq!(p.right[idx], ni as i32);
        }
    }

    /// CPU-side emulation of the tensor traversal must equal the scalar
    /// IntEngine — validates the packing before the XLA round-trip.
    #[test]
    fn packed_walk_matches_int_engine() {
        let m = model();
        let t = tier();
        let p = ForestPack::pack(&m, &t).unwrap();
        let engine = crate::inference::IntEngine::compile(&m);
        let ds = shuttle_like(64, 91);
        let (x, n_rows) = p.pack_input(&ds.features[..64 * 7], 7);
        assert_eq!(n_rows, 64);
        for b in 0..n_rows {
            let mut acc = vec![0u32; p.classes];
            for ti in 0..p.trees {
                let mut i = 0usize;
                for _ in 0..t.depth {
                    let idx = ti * p.nodes + i;
                    if p.left[idx] as usize == i && p.right[idx] as usize == i {
                        break;
                    }
                    let f = p.feat[idx] as usize;
                    let go_left = x[b * p.features + f] <= p.thresh[idx];
                    i = if go_left { p.left[idx] } else { p.right[idx] } as usize;
                }
                let idx = ti * p.nodes + i;
                for c in 0..p.classes {
                    acc[c] = acc[c].wrapping_add(p.leaf_val[idx * p.classes + c]);
                }
            }
            let want = engine.predict_fixed(ds.row(b));
            assert_eq!(&acc[..want.len()], &want[..], "row {b}");
            assert!(acc[want.len()..].iter().all(|&v| v == 0));
        }
    }

    #[test]
    fn pack_rejects_oversize() {
        let ds = shuttle_like(500, 92);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 30, max_depth: 5, ..Default::default() },
            1,
        );
        assert!(ForestPack::pack(&m, &tier()).is_err());
    }

    #[test]
    fn input_padding() {
        let m = model();
        let p = ForestPack::pack(&m, &tier()).unwrap();
        let rows = vec![1.0f32; 3 * 7];
        let (x, n) = p.pack_input(&rows, 7);
        assert_eq!(n, 3);
        assert_eq!(x.len(), 64 * 8);
        assert_eq!(x[0], crate::flint::ordered_u32(1.0));
        assert_eq!(x[7], 0); // padded feature column
        assert_eq!(x[3 * 8], 0); // padded row
    }
}
