//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Describes every compiled HLO tier (shapes, depth,
//! file name) so the runtime can pick the smallest tier a model fits.

use crate::ir::Model;
use crate::util::Json;
use std::path::Path;

/// One compiled artifact tier (fixed shapes baked at AOT time).
#[derive(Clone, Debug, PartialEq)]
pub struct Tier {
    pub name: String,
    pub file: String,
    pub batch: usize,
    pub features: usize,
    pub trees: usize,
    pub nodes: usize,
    pub classes: usize,
    pub depth: usize,
    pub use_pallas: bool,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tiers: Vec<Tier>,
}

impl Manifest {
    /// Load `manifest.json` from the artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        match v.get("format").and_then(Json::as_str) {
            Some("intreeger-artifacts-v1") => {}
            other => anyhow::bail!("unsupported artifact format {other:?}"),
        }
        let tiers_json = v
            .get("tiers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing tiers"))?;
        let mut tiers = Vec::new();
        for t in tiers_json {
            let field = |k: &str| -> anyhow::Result<usize> {
                t.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("manifest tier: bad field '{k}'"))
            };
            tiers.push(Tier {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("tier: missing name"))?
                    .to_string(),
                file: t
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("tier: missing file"))?
                    .to_string(),
                batch: field("B")?,
                features: field("F")?,
                trees: field("T")?,
                nodes: field("N")?,
                classes: field("C")?,
                depth: field("depth")?,
                use_pallas: matches!(t.get("use_pallas"), Some(Json::Bool(true))),
            });
        }
        Ok(Manifest { tiers })
    }

    /// Does `model` fit in `tier`?
    pub fn fits(model: &Model, tier: &Tier) -> bool {
        let max_nodes = model.trees.iter().map(|t| t.nodes.len()).max().unwrap_or(0);
        model.n_features <= tier.features
            && model.n_classes <= tier.classes
            && model.trees.len() <= tier.trees
            && max_nodes <= tier.nodes
            && model.max_depth() <= tier.depth
    }

    /// Pick the smallest pallas tier fitting `model` with batch >=
    /// `min_batch` (cost metric: padded tensor volume).
    pub fn pick(&self, model: &Model, min_batch: usize) -> Option<&Tier> {
        self.tiers
            .iter()
            .filter(|t| t.use_pallas && t.batch >= min_batch && Self::fits(model, t))
            .min_by_key(|t| t.trees * t.nodes * (t.classes + 4) + t.batch * t.features)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    const SAMPLE: &str = r#"{
        "format": "intreeger-artifacts-v1",
        "tiers": [
            {"name":"quick","file":"forest_quick.hlo.txt","B":64,"F":8,"T":16,"N":63,"C":8,"depth":6,"block_b":32,"use_pallas":true},
            {"name":"big","file":"forest_big.hlo.txt","B":256,"F":8,"T":64,"N":255,"C":8,"depth":8,"block_b":64,"use_pallas":true},
            {"name":"oracle","file":"forest_o.hlo.txt","B":64,"F":8,"T":16,"N":63,"C":8,"depth":6,"block_b":32,"use_pallas":false}
        ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tiers.len(), 3);
        assert_eq!(m.tiers[0].nodes, 63);
        assert!(m.tiers[0].use_pallas);
        assert!(!m.tiers[2].use_pallas);
    }

    #[test]
    fn parse_rejects_bad_format() {
        assert!(Manifest::parse("{\"format\":\"x\",\"tiers\":[]}").is_err());
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("nope").is_err());
    }

    #[test]
    fn pick_prefers_smallest_fitting() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let ds = shuttle_like(500, 80);
        let small = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
            1,
        );
        assert_eq!(m.pick(&small, 1).unwrap().name, "quick");
        let big = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 40, max_depth: 5, ..Default::default() },
            1,
        );
        assert_eq!(m.pick(&big, 1).unwrap().name, "big");
        // min_batch forces the bigger tier
        assert_eq!(m.pick(&small, 256).unwrap().name, "big");
        // nothing fits a 200-tree model
        let huge = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 80, max_depth: 5, ..Default::default() },
            1,
        );
        assert!(m.pick(&huge, 1).is_none());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !super::super::artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.tiers.iter().any(|t| t.name == "quick"));
        for t in &m.tiers {
            assert!(dir.join(&t.file).is_file(), "missing {}", t.file);
        }
    }
}
