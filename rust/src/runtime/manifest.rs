//! Artifact manifests.
//!
//! Two bundle formats live here:
//!
//! * [`Manifest`] — the XLA artifact contract between
//!   `python/compile/aot.py` and the rust runtime: every compiled HLO
//!   tier (shapes, depth, file name) so the runtime can pick the
//!   smallest tier a model fits.
//! * [`PipelineManifest`] — the output bundle of `intreeger pipeline`
//!   (model IR + generated C + report); the serving coordinator can
//!   boot straight from such a directory
//!   ([`crate::coordinator::server_from_pipeline`]).
//!
//! Both live in `manifest.json` of their respective directories and are
//! told apart by their `format` tag.

use crate::ir::{Model, MAX_CLASSES, MAX_FEATURES, MAX_NODES_PER_TREE, MAX_TREES};
use crate::util::json::{arr, num, obj, s, Json};
use std::path::Path;

/// Largest batch an artifact tier may declare. Tier shapes size host
/// buffers at load time, so a corrupt manifest must not be able to
/// demand a pathological allocation.
pub const MAX_TIER_BATCH: usize = 1 << 20;
/// Largest unrolled depth an artifact tier may declare.
pub const MAX_TIER_DEPTH: usize = 512;

/// One compiled artifact tier (fixed shapes baked at AOT time).
#[derive(Clone, Debug, PartialEq)]
pub struct Tier {
    /// Tier name (e.g. `quick`, `big`).
    pub name: String,
    /// HLO file name inside the artifact directory.
    pub file: String,
    /// Batch rows the executable was compiled for.
    pub batch: usize,
    /// Padded feature count.
    pub features: usize,
    /// Padded tree count.
    pub trees: usize,
    /// Padded nodes per tree.
    pub nodes: usize,
    /// Padded class count.
    pub classes: usize,
    /// Maximum tree depth the lowered loop unrolls to.
    pub depth: usize,
    /// Whether this tier is the Pallas-lowered kernel (vs the oracle).
    pub use_pallas: bool,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Every compiled tier the artifact directory offers.
    pub tiers: Vec<Tier>,
}

impl Manifest {
    /// Load `manifest.json` from the artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        match v.get("format").and_then(Json::as_str) {
            Some("intreeger-artifacts-v1") => {}
            other => anyhow::bail!("unsupported artifact format {other:?}"),
        }
        let tiers_json = v
            .get("tiers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing tiers"))?;
        let mut tiers = Vec::new();
        for t in tiers_json {
            // Each shape field must be positive and inside the same
            // capacity limits the IR enforces — tier shapes size host
            // buffers, so they are admission-checked like model files.
            let field = |k: &str| -> anyhow::Result<usize> {
                let limit = match k {
                    "B" => MAX_TIER_BATCH,
                    "F" => MAX_FEATURES,
                    "T" => MAX_TREES,
                    "N" => MAX_NODES_PER_TREE,
                    "C" => MAX_CLASSES,
                    _ => MAX_TIER_DEPTH,
                };
                let v = t
                    .get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("manifest tier: bad field '{k}'"))?;
                if v == 0 || v > limit {
                    anyhow::bail!("manifest tier: field '{k}' = {v} outside 1..={limit}");
                }
                Ok(v)
            };
            tiers.push(Tier {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("tier: missing name"))?
                    .to_string(),
                file: t
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("tier: missing file"))?
                    .to_string(),
                batch: field("B")?,
                features: field("F")?,
                trees: field("T")?,
                nodes: field("N")?,
                classes: field("C")?,
                depth: field("depth")?,
                use_pallas: matches!(t.get("use_pallas"), Some(Json::Bool(true))),
            });
        }
        Ok(Manifest { tiers })
    }

    /// Does `model` fit in `tier`?
    pub fn fits(model: &Model, tier: &Tier) -> bool {
        let max_nodes = model.trees.iter().map(|t| t.nodes.len()).max().unwrap_or(0);
        model.n_features <= tier.features
            && model.n_classes <= tier.classes
            && model.trees.len() <= tier.trees
            && max_nodes <= tier.nodes
            && model.max_depth() <= tier.depth
    }

    /// Pick the smallest pallas tier fitting `model` with batch >=
    /// `min_batch` (cost metric: padded tensor volume).
    pub fn pick(&self, model: &Model, min_batch: usize) -> Option<&Tier> {
        self.tiers
            .iter()
            .filter(|t| t.use_pallas && t.batch >= min_batch && Self::fits(model, t))
            .min_by_key(|t| t.trees * t.nodes * (t.classes + 4) + t.batch * t.features)
    }
}

// ---------------------------------------------------------------------------
// Pipeline artifact bundle
// ---------------------------------------------------------------------------

/// Format tag of a pipeline bundle's `manifest.json`.
pub const PIPELINE_FORMAT: &str = "intreeger-pipeline-v1";

/// One model inside a pipeline bundle.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineModelEntry {
    /// `"rf"` or `"gbt"`.
    pub kind: String,
    /// Model IR file name inside the bundle directory.
    pub model_file: String,
    /// Generated C file name (None for model kinds without C emission).
    pub c_file: Option<String>,
    /// C layout the bundle was generated with.
    pub layout: String,
    /// Numeric variant of the generated C.
    pub variant: String,
}

/// The `manifest.json` of an `intreeger pipeline` output directory —
/// the machine-readable table of contents the serving coordinator and
/// downstream tooling navigate the bundle with.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineManifest {
    /// Seed the pipeline ran with (bit-reproducibility record). Stored
    /// as a JSON number, so it must not exceed 2^53 — `pipeline::run`
    /// rejects larger seeds up front.
    pub seed: u64,
    /// Report file name inside the bundle directory (`report.json`).
    pub report_file: String,
    /// One entry per trained model.
    pub models: Vec<PipelineModelEntry>,
}

impl PipelineManifest {
    /// Serialize to the bundle's `manifest.json` schema.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("format", s(PIPELINE_FORMAT)),
            ("seed", num(self.seed as f64)),
            ("report", s(&self.report_file)),
            (
                "models",
                arr(self.models.iter().map(|m| {
                    obj(vec![
                        ("kind", s(&m.kind)),
                        ("model", s(&m.model_file)),
                        (
                            "c",
                            match &m.c_file {
                                Some(f) => s(f),
                                None => Json::Null,
                            },
                        ),
                        ("layout", s(&m.layout)),
                        ("variant", s(&m.variant)),
                    ])
                })),
            ),
        ])
    }

    /// Write `manifest.json` into a bundle directory.
    pub fn write(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::write(dir.join("manifest.json"), self.to_json().to_string())?;
        Ok(())
    }

    /// Parse a bundle manifest, rejecting other formats (notably the XLA
    /// artifact manifest, which shares the file name).
    pub fn parse(text: &str) -> anyhow::Result<PipelineManifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("pipeline manifest: {e}"))?;
        match v.get("format").and_then(Json::as_str) {
            Some(PIPELINE_FORMAT) => {}
            other => anyhow::bail!("not a pipeline bundle (format {other:?})"),
        }
        let seed = v
            .get("seed")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("pipeline manifest: missing seed"))? as u64;
        let report_file = v
            .get("report")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("pipeline manifest: missing report"))?
            .to_string();
        let models_json = v
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("pipeline manifest: missing models"))?;
        let mut models = Vec::new();
        for m in models_json {
            let field = |k: &str| -> anyhow::Result<String> {
                m.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("pipeline manifest model: bad field '{k}'"))
            };
            models.push(PipelineModelEntry {
                kind: field("kind")?,
                model_file: field("model")?,
                c_file: m.get("c").and_then(Json::as_str).map(str::to_string),
                layout: field("layout")?,
                variant: field("variant")?,
            });
        }
        Ok(PipelineManifest { seed, report_file, models })
    }

    /// Load `manifest.json` from a pipeline bundle directory.
    pub fn load(dir: &Path) -> anyhow::Result<PipelineManifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    /// Load the model IR of the bundle's entry of the given kind.
    pub fn load_model(&self, dir: &Path, kind: &str) -> anyhow::Result<Model> {
        let entry = self
            .models
            .iter()
            .find(|m| m.kind == kind)
            .ok_or_else(|| anyhow::anyhow!("pipeline bundle has no '{kind}' model"))?;
        let text = std::fs::read_to_string(dir.join(&entry.model_file))?;
        Model::from_json(&text).map_err(|e| anyhow::anyhow!("loading {}: {e}", entry.model_file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shuttle_like;
    use crate::trees::{ForestParams, RandomForest};

    const SAMPLE: &str = r#"{
        "format": "intreeger-artifacts-v1",
        "tiers": [
            {"name":"quick","file":"forest_quick.hlo.txt","B":64,"F":8,"T":16,"N":63,"C":8,"depth":6,"block_b":32,"use_pallas":true},
            {"name":"big","file":"forest_big.hlo.txt","B":256,"F":8,"T":64,"N":255,"C":8,"depth":8,"block_b":64,"use_pallas":true},
            {"name":"oracle","file":"forest_o.hlo.txt","B":64,"F":8,"T":16,"N":63,"C":8,"depth":6,"block_b":32,"use_pallas":false}
        ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tiers.len(), 3);
        assert_eq!(m.tiers[0].nodes, 63);
        assert!(m.tiers[0].use_pallas);
        assert!(!m.tiers[2].use_pallas);
    }

    #[test]
    fn parse_rejects_bad_format() {
        assert!(Manifest::parse("{\"format\":\"x\",\"tiers\":[]}").is_err());
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("nope").is_err());
    }

    #[test]
    fn parse_rejects_out_of_bounds_tier_shapes() {
        // Tier shapes size host buffers; zero and absurd values are
        // admission errors, not later allocation failures.
        let tier = |b: usize, n: usize| {
            format!(
                r#"{{"format":"intreeger-artifacts-v1","tiers":[
                    {{"name":"t","file":"f.hlo.txt","B":{b},"F":8,"T":16,"N":{n},"C":8,"depth":6,"use_pallas":true}}]}}"#
            )
        };
        assert!(Manifest::parse(&tier(64, 63)).is_ok());
        assert!(Manifest::parse(&tier(0, 63)).is_err(), "zero batch");
        assert!(Manifest::parse(&tier(1 << 30, 63)).is_err(), "absurd batch");
        assert!(Manifest::parse(&tier(64, 999_999_999)).is_err(), "absurd node count");
    }

    #[test]
    fn pick_prefers_smallest_fitting() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let ds = shuttle_like(500, 80);
        let small = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 8, max_depth: 5, ..Default::default() },
            1,
        );
        assert_eq!(m.pick(&small, 1).unwrap().name, "quick");
        let big = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 40, max_depth: 5, ..Default::default() },
            1,
        );
        assert_eq!(m.pick(&big, 1).unwrap().name, "big");
        // min_batch forces the bigger tier
        assert_eq!(m.pick(&small, 256).unwrap().name, "big");
        // nothing fits a 200-tree model
        let huge = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 80, max_depth: 5, ..Default::default() },
            1,
        );
        assert!(m.pick(&huge, 1).is_none());
    }

    fn sample_pipeline_manifest() -> PipelineManifest {
        PipelineManifest {
            seed: 42,
            report_file: "report.json".into(),
            models: vec![
                PipelineModelEntry {
                    kind: "rf".into(),
                    model_file: "model_rf.json".into(),
                    c_file: Some("model_rf.c".into()),
                    layout: "ifelse".into(),
                    variant: "intreeger".into(),
                },
                PipelineModelEntry {
                    kind: "gbt".into(),
                    model_file: "model_gbt.json".into(),
                    c_file: None,
                    layout: "ifelse".into(),
                    variant: "intreeger".into(),
                },
            ],
        }
    }

    #[test]
    fn pipeline_manifest_roundtrips() {
        let m = sample_pipeline_manifest();
        let back = PipelineManifest::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.models[1].c_file, None);
    }

    #[test]
    fn pipeline_manifest_rejects_other_formats() {
        // The XLA artifact manifest shares the file name but not the tag.
        assert!(PipelineManifest::parse(SAMPLE).is_err());
        assert!(PipelineManifest::parse("{}").is_err());
        assert!(PipelineManifest::parse("nope").is_err());
        // And vice versa: the tier manifest parser rejects bundles.
        let bundle = sample_pipeline_manifest().to_json().to_string();
        assert!(Manifest::parse(&bundle).is_err());
    }

    #[test]
    fn pipeline_manifest_write_load_and_model() {
        let dir = std::env::temp_dir()
            .join(format!("intreeger_pipe_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample_pipeline_manifest();
        m.write(&dir).unwrap();
        let back = PipelineManifest::load(&dir).unwrap();
        assert_eq!(m, back);
        // load_model: write a real model file under the rf entry.
        let ds = shuttle_like(200, 90);
        let model = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 2, max_depth: 3, ..Default::default() },
            1,
        );
        std::fs::write(dir.join("model_rf.json"), model.to_json()).unwrap();
        let loaded = back.load_model(&dir, "rf").unwrap();
        assert_eq!(loaded, model);
        assert!(back.load_model(&dir, "nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !super::super::artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.tiers.iter().any(|t| t.name == "quick"));
        for t in &m.tiers {
            assert!(dir.join(&t.file).is_file(), "missing {}", t.file);
        }
    }
}
