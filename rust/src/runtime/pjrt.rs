//! PJRT executable wrapper: compile once, execute many.
//!
//! Follows the verified /opt/xla-example/load_hlo pattern: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`, with the lowered-with-
//! `return_tuple=True` output unwrapped via `to_tuple1()`.

use super::manifest::Tier;
use super::pack::ForestPack;
use std::path::Path;

/// A compiled forest-inference executable bound to one packed model.
///
/// §Perf: the forest tensors (~0.8 MB for the serving tiers) are
/// transferred to device buffers **once at load**; each `execute` call
/// only uploads the batch's feature words. Re-transferring the forest as
/// literals per call dominated the execution profile (≈10x the actual
/// compute on the CPU plugin).
pub struct PjrtEngine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    tier: Tier,
    pack: ForestPack,
    /// Pre-transferred forest buffers (constant across calls).
    forest_buffers: Vec<xla::PjRtBuffer>,
}

impl PjrtEngine {
    /// Compile the tier's HLO on the PJRT CPU client and bind the packed
    /// model's forest tensors.
    pub fn load(artifacts_dir: &Path, tier: Tier, pack: ForestPack) -> anyhow::Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(artifacts_dir.join(&tier.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;

        let (t, n, c) = (tier.trees, tier.nodes, tier.classes);
        let forest_buffers = vec![
            client.buffer_from_host_buffer(&pack.feat, &[t, n], None)?,
            client.buffer_from_host_buffer(&pack.thresh, &[t, n], None)?,
            client.buffer_from_host_buffer(&pack.left, &[t, n], None)?,
            client.buffer_from_host_buffer(&pack.right, &[t, n], None)?,
            client.buffer_from_host_buffer(&pack.leaf_val, &[t, n, c], None)?,
        ];
        Ok(PjrtEngine { client, exe, tier, pack, forest_buffers })
    }

    /// The artifact tier this engine was compiled from.
    pub fn tier(&self) -> &Tier {
        &self.tier
    }

    /// The padded forest tensors bound to the executable.
    pub fn pack(&self) -> &ForestPack {
        &self.pack
    }

    /// Maximum rows per call.
    pub fn max_batch(&self) -> usize {
        self.tier.batch
    }

    /// Execute a batch of float rows (row-major, the model's feature
    /// count). Returns one u32 fixed-point accumulator vector per row
    /// (length = the model's class count).
    pub fn execute(&self, rows: &[f32], model_features: usize) -> anyhow::Result<Vec<Vec<u32>>> {
        let (x, n_rows) = self.pack.pack_input(rows, model_features);
        let x_buf = self
            .client
            .buffer_from_host_buffer(&x, &[self.tier.batch, self.tier.features], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(6);
        args.push(&x_buf);
        for b in &self.forest_buffers {
            args.push(b);
        }
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let flat = out.to_vec::<u32>()?;
        anyhow::ensure!(
            flat.len() == self.tier.batch * self.tier.classes,
            "unexpected output size {}",
            flat.len()
        );
        let c = self.tier.classes;
        let mc = self.pack.model_classes;
        Ok((0..n_rows).map(|r| flat[r * c..r * c + mc].to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {

    use crate::data::shuttle_like;
    use crate::inference::{Engine, IntEngine};
    use crate::ir::argmax;
    use crate::runtime::{artifacts_available, engine_for_model};
    use crate::trees::{ForestParams, RandomForest};

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn xla_matches_scalar_int_engine_bit_exactly() {
        let dir = artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("artifacts not built (run `make artifacts`); skipping");
            return;
        }
        let ds = shuttle_like(2000, 95);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
            7,
        );
        let engine = engine_for_model(&dir, &m, 1).expect("load engine");
        let scalar = IntEngine::compile(&m);

        let batch = engine.max_batch().min(64);
        let rows = &ds.features[..batch * ds.n_features];
        let got = engine.execute(rows, ds.n_features).expect("execute");
        assert_eq!(got.len(), batch);
        for (i, fixed) in got.iter().enumerate() {
            let want = scalar.predict_fixed(ds.row(i));
            assert_eq!(fixed, &want, "row {i}");
            // argmax agreement implies prediction parity
            assert_eq!(argmax(fixed), scalar.predict(ds.row(i)));
        }
    }

    #[test]
    fn partial_batches_work() {
        let dir = artifacts_dir();
        if !artifacts_available(&dir) {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let ds = shuttle_like(100, 96);
        let m = RandomForest::train(
            &ds,
            &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() },
            2,
        );
        let engine = engine_for_model(&dir, &m, 1).unwrap();
        let scalar = IntEngine::compile(&m);
        let got = engine.execute(&ds.features[..3 * 7], 7).unwrap();
        assert_eq!(got.len(), 3);
        for i in 0..3 {
            assert_eq!(got[i], scalar.predict_fixed(ds.row(i)));
        }
    }
}
