//! Deployment runtime: the zero-copy binary model format ([`binfmt`])
//! and the PJRT/XLA execution path.
//!
//! The PJRT half loads the AOT-compiled JAX/Pallas forest-inference
//! artifacts (HLO text, produced once by `python/compile/aot.py`) and
//! executes them from rust. Python is never on this path.
//!
//! Offline builds link the vendored `xla` **stub** (`rust/vendor/xla`),
//! whose client constructor fails fast — [`PjrtEngine::load`] then
//! returns an error and callers fall back to the scalar batched route
//! ([`crate::inference::IntEngine::predict_fixed_batch`]). Swapping the
//! path dependency for the real bindings re-enables this path without
//! source changes.
//!
//! Flow: [`Manifest::load`] reads `artifacts/manifest.json` →
//! [`pack::ForestPack`] pads an IR model into the smallest fitting tier →
//! [`PjrtEngine::load`] compiles the tier's HLO once on the PJRT CPU
//! client → [`PjrtEngine::execute`] runs batches of order-preserved u32
//! feature words and returns u32 fixed-point class accumulators —
//! bit-identical to the scalar [`crate::inference::IntEngine`] (verified
//! by `rust/tests/xla_parity.rs`).

pub mod binfmt;
pub mod manifest;
pub mod pack;
pub mod pjrt;

pub use binfmt::{BinError, BinKind, BinView, FileBin, OwnedBin};
#[cfg(unix)]
pub use binfmt::MappedBin;
pub use manifest::{Manifest, PipelineManifest, PipelineModelEntry, Tier, PIPELINE_FORMAT};
pub use pack::ForestPack;
pub use pjrt::PjrtEngine;

use crate::ir::Model;
use std::path::Path;

/// Load the best engine for a model from an artifact directory: picks
/// the smallest tier that fits, packs the model, compiles the HLO.
pub fn engine_for_model(
    artifacts_dir: &Path,
    model: &Model,
    min_batch: usize,
) -> anyhow::Result<PjrtEngine> {
    let manifest = Manifest::load(artifacts_dir)?;
    let tier = manifest
        .pick(model, min_batch)
        .ok_or_else(|| anyhow::anyhow!("no artifact tier fits the model"))?;
    let pack = ForestPack::pack(model, tier)?;
    PjrtEngine::load(artifacts_dir, tier.clone(), pack)
}

/// True when an artifact directory looks usable (manifest present).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").is_file()
}
