//! E6 — reproduces §IV-E: the SiFive FE310 (RV32IMAC @ 16 MHz, no FPU)
//! microcontroller use case. Shuttle RF, 30 trees, max depth 5,
//! integer-only if-else code, XIP from QSPI flash.
//!
//! Paper numbers: text 42 382 B, data 8 B, bss 1 152 B; IPC 0.746
//! (QSPI-fetch bound); we also show what the float variant *would* cost
//! (soft-float calls — the reason integer-only inference enables this
//! class of device at all).

use intreeger::data::shuttle_like;
use intreeger::inference::Variant;
use intreeger::simarch::{self, fe310, Core};
use intreeger::trees::{ForestParams, RandomForest};

fn main() {
    println!("§IV-E — FE310 microcontroller use case (simulated; DESIGN.md §Substitutions)");

    let ds = shuttle_like(58_000, 4); // full paper-scale dataset
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 30, max_depth: 5, ..Default::default() },
        11,
    );
    let stats = intreeger::ir::stats::stats(&model);
    println!(
        "\nmodel: {} trees, {} nodes ({} branches / {} leaves), max depth {}",
        stats.n_trees, stats.n_nodes, stats.n_branches, stats.n_leaves, stats.max_depth
    );

    let r = fe310::use_case(&model, &ds, 400);
    println!("\nmemory footprint (integer-only if-else, rv32imac_zicsr_zifencei / ilp32):");
    println!("  text: {:>7} B   (paper: 42,382 B)", r.footprint.text_bytes);
    println!("  data: {:>7} B   (paper:      8 B)", r.footprint.data_bytes);
    println!("  bss:  {:>7} B   (paper:  1,152 B)", r.footprint.bss_bytes);
    println!("  total:{:>7} B   (paper: 43,542 B)", r.footprint.total());

    println!("\nper-inference dynamics @ 16 MHz:");
    println!("  instructions: {:>12.0}", r.instructions_per_inference);
    println!("  cycles:       {:>12.0}", r.cycles_per_inference);
    println!("  IPC:          {:>12.3}   (paper: 0.746, QSPI-fetch bound)", r.ipc);
    println!("  inference/s:  {:>12.1}", r.inferences_per_second);
    println!("  s/inference:  {:>12.6}", r.seconds_per_inference);

    // What float inference would cost on this FPU-less part (soft-float).
    let f = simarch::simulate(&model, &ds, Variant::Float, Core::Fe310, 400);
    let i = simarch::simulate(&model, &ds, Variant::IntTreeger, Core::Fe310, 400);
    println!("\nfloat (soft-float libgcc) vs integer-only on the FPU-less FE310:");
    println!("  float:     {:>12.0} cycles/inference", f.cycles);
    println!("  intreeger: {:>12.0} cycles/inference  => {:.1}x speedup", i.cycles, f.cycles / i.cycles);
    println!("\nconclusion (paper): integer-only inference makes tree ensembles practical on");
    println!("ultra-low-power devices without FPUs; the model fits QSPI flash with RAM to spare.");
}
