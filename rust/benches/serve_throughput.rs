//! E9 — serving-stack benchmark: scalar engine (per-row and tiled
//! batch kernel) vs the AOT-compiled XLA/Pallas batched engine, the
//! batch-size crossover the coordinator's router exploits, and
//! end-to-end server throughput with dynamic batching across a sharded
//! worker pool — plus the ISSUE-6 question: at a **fixed core budget**,
//! is it better to spend cores on worker shards (inter-batch
//! parallelism), on the intra-batch tile scheduler, or on a mix?

use intreeger::coordinator::{BatchPolicy, InferenceServer, ServerConfig};
use intreeger::data::shuttle_like;
use intreeger::inference::IntEngine;
use intreeger::runtime::{artifacts_available, engine_for_model};
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure, report, section};
use std::path::Path;
use std::time::Duration;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ds = shuttle_like(12_000, 7);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        19,
    );
    let scalar = IntEngine::compile(&model);

    section("scalar engine: per-row loop vs tiled batch kernel");
    let rows: Vec<&[f32]> = (0..2000).map(|i| ds.row(i)).collect();
    let m = measure(2, 7, rows.len() as u64, || {
        let mut acc = 0u32;
        for r in &rows {
            acc ^= scalar.predict_fixed(r)[0];
        }
        black_box(acc);
    });
    report("scalar/predict_fixed (per-row)", &m);
    let flat: Vec<f32> = ds.features[..2000 * ds.n_features].to_vec();
    let mb = measure(2, 7, 2000, || {
        let out = scalar.predict_fixed_batch(&flat);
        black_box(out[0][0]);
    });
    report("scalar/predict_fixed_batch (tiled)", &mb);
    println!(
        "batch kernel speedup over per-row: {:.2}x",
        m.per_item_ns() / mb.per_item_ns()
    );

    section("end-to-end server: worker pool scaling (scalar route)");
    for n_workers in [1usize, 2, 4] {
        let server = InferenceServer::start(
            &model,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) },
                n_workers,
                ..Default::default()
            },
        );
        let n = 6000usize;
        let reqs: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let t0 = std::time::Instant::now();
        let responses = server.infer_many(reqs);
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        println!(
            "workers {n_workers}: {:>8.0} req/s  p50 {:>6.0} us  p99 {:>7.0} us  (mean batch {:.1}, batch service p99 {:.0} us)",
            n as f64 / wall,
            snap.latency_p50_us,
            snap.latency_p99_us,
            snap.mean_batch,
            snap.batch_latency_p99_us
        );
        black_box(responses.len());
    }

    // Fixed core budget B: B workers x 1 thread (pure sharding) vs
    // 1 worker x B threads (pure intra-batch splitting) vs B/2 x 2
    // (combined). Large max_batch so the tile scheduler has rows to
    // split; the threads knob reaches the server's engines through the
    // same INTREEGER_THREADS override operators use (engines resolve it
    // at server start).
    section("fixed core budget: worker shards vs intra-batch threads vs combined");
    let budget = intreeger::inference::parallel::detected().clamp(1, 4);
    println!(
        "core budget {budget} (of {} logical cores)",
        intreeger::inference::parallel::detected()
    );
    let mut configs: Vec<(String, usize, usize)> = vec![
        (format!("{budget} workers x 1 thread"), budget, 1),
        (format!("1 worker x {budget} threads"), 1, budget),
    ];
    if budget >= 4 {
        configs.push((format!("{} workers x 2 threads", budget / 2), budget / 2, 2));
    }
    let prior_threads = std::env::var(intreeger::inference::THREADS_ENV).ok();
    for (label, n_workers, threads) in configs {
        std::env::set_var(intreeger::inference::THREADS_ENV, threads.to_string());
        let server = InferenceServer::start(
            &model,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 256, max_wait: Duration::from_micros(300) },
                n_workers,
                ..Default::default()
            },
        );
        let n = 6000usize;
        let reqs: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let t0 = std::time::Instant::now();
        let responses = server.infer_many(reqs);
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        println!(
            "{label:<24} {:>8.0} req/s  p50 {:>6.0} us  p99 {:>7.0} us  (mean batch {:.1}, batch service p99 {:.0} us)",
            n as f64 / wall,
            snap.latency_p50_us,
            snap.latency_p99_us,
            snap.mean_batch,
            snap.batch_latency_p99_us
        );
        black_box(responses.len());
    }
    match prior_threads {
        Some(v) => std::env::set_var(intreeger::inference::THREADS_ENV, v),
        None => std::env::remove_var(intreeger::inference::THREADS_ENV),
    }

    if !artifacts_available(&dir) {
        println!("\n(artifacts not built — run `make artifacts` for the XLA comparisons)");
        return;
    }

    section("XLA/PJRT batched engine (AOT Pallas artifact) vs scalar, by batch size");
    let xla = engine_for_model(&dir, &model, 1).expect("xla engine");
    println!(
        "tier: {} (B={} T={} N={} C={})",
        xla.tier().name,
        xla.tier().batch,
        xla.tier().trees,
        xla.tier().nodes,
        xla.tier().classes
    );
    for batch in [1usize, 4, 16, 64] {
        let batch = batch.min(xla.max_batch());
        let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
        let mx = measure(2, 7, batch as u64, || {
            let out = xla.execute(&flat, ds.n_features).expect("xla exec");
            black_box(out[0][0]);
        });
        // Honest baseline: the scalar route is batch-first now, so the
        // XLA crossover must beat the tiled kernel, not a per-row loop.
        let ms = measure(2, 7, batch as u64, || {
            let out = scalar.predict_fixed_batch(&flat);
            black_box(out[0][0]);
        });
        println!(
            "batch {batch:>4}: xla {:>10.1} ns/row  scalar-batched {:>10.1} ns/row  ({})",
            mx.per_item_ns(),
            ms.per_item_ns(),
            if mx.per_item_ns() < ms.per_item_ns() { "xla wins" } else { "scalar wins" }
        );
    }

    section("end-to-end server throughput (dynamic batching)");
    for (label, policy, threshold) in [
        ("scalar-only small batches", BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }, usize::MAX),
        ("xla offload large batches", BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(300) }, 16),
    ] {
        let server = InferenceServer::start(
            &model,
            Some(dir.clone()),
            ServerConfig {
                policy,
                xla_threshold: threshold,
                queue_depth: 4096,
                auto_calibrate: false, // measure both routes explicitly
                n_workers: 1,          // isolate routing from pool scaling
            },
        );
        let n = 4000usize;
        let reqs: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let t0 = std::time::Instant::now();
        let responses = server.infer_many(reqs);
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        println!(
            "{label:<28} {:>8.0} req/s  p50 {:>6.0} us  p99 {:>7.0} us  (scalar rows {}, xla rows {}, mean batch {:.1})",
            n as f64 / wall,
            snap.latency_p50_us,
            snap.latency_p99_us,
            snap.rows_scalar,
            snap.rows_xla,
            snap.mean_batch
        );
        black_box(responses.len());
    }
}
