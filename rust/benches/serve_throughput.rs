//! E9 — serving-stack benchmark: scalar engine (per-row and tiled
//! batch kernel) vs the AOT-compiled XLA/Pallas batched engine, the
//! batch-size crossover the coordinator's router exploits, and
//! end-to-end server throughput with dynamic batching across a sharded
//! worker pool — plus the ISSUE-6 question: at a **fixed core budget**,
//! is it better to spend cores on worker shards (inter-batch
//! parallelism), on the intra-batch tile scheduler, or on a mix?
//!
//! ISSUE 7 adds the **overload section**: an open-loop flood at offered
//! load ≥ 2x measured capacity against a small admission queue, showing
//! the failure model at work — goodput (accepted req/s actually
//! answered), shed rate, and the latency p99 **of accepted requests**
//! (the point of load shedding: admitted work keeps its latency). The
//! section writes a machine-readable `BENCH_serve.json` at the repo root
//! (path overridable via `INTREEGER_SERVE_JSON`); `BENCH_SMOKE=1` runs
//! the reduced-size CI variant with an identical schema.
//!
//! ISSUE 8 adds the **Poisson saturation curve** (schema 2): instead of
//! a single flat-out flood, an open-loop arrival process with
//! deterministic seeded exponential inter-arrival times sweeps offered
//! load through fractions and multiples of the measured capacity
//! (0.5x, 0.9x, 1.2x, 2.0x). Each point runs against a fresh server
//! with a 5 ms TTL and reports goodput, shed rate, and the accepted-
//! request p50/p99 — the classic saturation story: latency flat below
//! the knee, shed + TTL expiry absorbing everything above it, and the
//! accounting identity `ok + shed + expired + lost == offered` holding
//! at every point.
//!
//! ISSUE 10 adds the **admission path comparison** (schema 3): the same
//! closed-loop workload through the clone-per-request `submit` path
//! (heap `Vec<f32>` + fresh reply channel per request) and through the
//! slab path (`checkout_row` into the arena + one reused `ReplySlot`),
//! quantifying what the zero-alloc hot path buys at admission time.

use intreeger::coordinator::{BatchPolicy, InferenceServer, ReplySlot, ServeError, ServerConfig};
use intreeger::data::shuttle_like;
use intreeger::inference::IntEngine;
use intreeger::runtime::{artifacts_available, engine_for_model};
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure, report, section};
use intreeger::util::json::{arr, num, obj, s, Json};
use intreeger::util::Rng;
use std::path::Path;
use std::time::{Duration, Instant};

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ds = shuttle_like(12_000, 7);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        19,
    );
    let scalar = IntEngine::compile(&model);

    section("scalar engine: per-row loop vs tiled batch kernel");
    let rows: Vec<&[f32]> = (0..2000).map(|i| ds.row(i)).collect();
    let m = measure(2, 7, rows.len() as u64, || {
        let mut acc = 0u32;
        for r in &rows {
            acc ^= scalar.predict_fixed(r)[0];
        }
        black_box(acc);
    });
    report("scalar/predict_fixed (per-row)", &m);
    let flat: Vec<f32> = ds.features[..2000 * ds.n_features].to_vec();
    let mb = measure(2, 7, 2000, || {
        let out = scalar.predict_fixed_batch(&flat);
        black_box(out[0][0]);
    });
    report("scalar/predict_fixed_batch (tiled)", &mb);
    println!(
        "batch kernel speedup over per-row: {:.2}x",
        m.per_item_ns() / mb.per_item_ns()
    );

    section("end-to-end server: worker pool scaling (scalar route)");
    for n_workers in [1usize, 2, 4] {
        let server = InferenceServer::start(
            &model,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) },
                n_workers,
                ..Default::default()
            },
        );
        let n = 6000usize;
        let reqs: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let t0 = std::time::Instant::now();
        let responses = server.infer_many(reqs);
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        println!(
            "workers {n_workers}: {:>8.0} req/s  p50 {:>6.0} us  p99 {:>7.0} us  (mean batch {:.1}, batch service p99 {:.0} us)",
            n as f64 / wall,
            snap.latency_p50_us,
            snap.latency_p99_us,
            snap.mean_batch,
            snap.batch_latency_p99_us
        );
        black_box(responses.iter().filter(|r| r.is_ok()).count());
    }

    // Fixed core budget B: B workers x 1 thread (pure sharding) vs
    // 1 worker x B threads (pure intra-batch splitting) vs B/2 x 2
    // (combined). Large max_batch so the tile scheduler has rows to
    // split; the threads knob reaches the server's engines through the
    // same INTREEGER_THREADS override operators use (engines resolve it
    // at server start).
    section("fixed core budget: worker shards vs intra-batch threads vs combined");
    let budget = intreeger::inference::parallel::detected().clamp(1, 4);
    println!(
        "core budget {budget} (of {} logical cores)",
        intreeger::inference::parallel::detected()
    );
    let mut configs: Vec<(String, usize, usize)> = vec![
        (format!("{budget} workers x 1 thread"), budget, 1),
        (format!("1 worker x {budget} threads"), 1, budget),
    ];
    if budget >= 4 {
        configs.push((format!("{} workers x 2 threads", budget / 2), budget / 2, 2));
    }
    let prior_threads = std::env::var(intreeger::inference::THREADS_ENV).ok();
    for (label, n_workers, threads) in configs {
        std::env::set_var(intreeger::inference::THREADS_ENV, threads.to_string());
        let server = InferenceServer::start(
            &model,
            None,
            ServerConfig {
                policy: BatchPolicy { max_batch: 256, max_wait: Duration::from_micros(300) },
                n_workers,
                ..Default::default()
            },
        );
        let n = 6000usize;
        let reqs: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let t0 = std::time::Instant::now();
        let responses = server.infer_many(reqs);
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        println!(
            "{label:<24} {:>8.0} req/s  p50 {:>6.0} us  p99 {:>7.0} us  (mean batch {:.1}, batch service p99 {:.0} us)",
            n as f64 / wall,
            snap.latency_p50_us,
            snap.latency_p99_us,
            snap.mean_batch,
            snap.batch_latency_p99_us
        );
        black_box(responses.iter().filter(|r| r.is_ok()).count());
    }
    match prior_threads {
        Some(v) => std::env::set_var(intreeger::inference::THREADS_ENV, v),
        None => std::env::remove_var(intreeger::inference::THREADS_ENV),
    }

    overload_section(&model, &ds);

    if !artifacts_available(&dir) {
        println!("\n(artifacts not built — run `make artifacts` for the XLA comparisons)");
        return;
    }

    section("XLA/PJRT batched engine (AOT Pallas artifact) vs scalar, by batch size");
    let xla = engine_for_model(&dir, &model, 1).expect("xla engine");
    println!(
        "tier: {} (B={} T={} N={} C={})",
        xla.tier().name,
        xla.tier().batch,
        xla.tier().trees,
        xla.tier().nodes,
        xla.tier().classes
    );
    for batch in [1usize, 4, 16, 64] {
        let batch = batch.min(xla.max_batch());
        let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
        let mx = measure(2, 7, batch as u64, || {
            let out = xla.execute(&flat, ds.n_features).expect("xla exec");
            black_box(out[0][0]);
        });
        // Honest baseline: the scalar route is batch-first now, so the
        // XLA crossover must beat the tiled kernel, not a per-row loop.
        let ms = measure(2, 7, batch as u64, || {
            let out = scalar.predict_fixed_batch(&flat);
            black_box(out[0][0]);
        });
        println!(
            "batch {batch:>4}: xla {:>10.1} ns/row  scalar-batched {:>10.1} ns/row  ({})",
            mx.per_item_ns(),
            ms.per_item_ns(),
            if mx.per_item_ns() < ms.per_item_ns() { "xla wins" } else { "scalar wins" }
        );
    }

    section("end-to-end server throughput (dynamic batching)");
    for (label, policy, threshold) in [
        ("scalar-only small batches", BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }, usize::MAX),
        ("xla offload large batches", BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(300) }, 16),
    ] {
        let server = InferenceServer::start(
            &model,
            Some(dir.clone()),
            ServerConfig {
                policy,
                xla_threshold: threshold,
                queue_depth: 4096,
                auto_calibrate: false, // measure both routes explicitly
                n_workers: 1,          // isolate routing from pool scaling
                ..Default::default()
            },
        );
        let n = 4000usize;
        let reqs: Vec<Vec<f32>> = (0..n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        let t0 = std::time::Instant::now();
        let responses = server.infer_many(reqs);
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        println!(
            "{label:<28} {:>8.0} req/s  p50 {:>6.0} us  p99 {:>7.0} us  (scalar rows {}, xla rows {}, mean batch {:.1})",
            n as f64 / wall,
            snap.latency_p50_us,
            snap.latency_p99_us,
            snap.rows_scalar,
            snap.rows_xla,
            snap.mean_batch
        );
        black_box(responses.iter().filter(|r| r.is_ok()).count());
    }
}

/// ISSUE-7 overload study. Two runs against the same small-queue config:
///
/// 1. **capacity probe** — a closed-loop `infer_many` (blocking clients,
///    every request resolves) measures what the server can actually
///    sustain;
/// 2. **open-loop flood** — raw `submit_with_ttl` as fast as the client
///    can go (submission is orders of magnitude cheaper than serving, so
///    offered load lands far above 2x capacity) against a 256-deep
///    admission queue with a 5 ms TTL. Overflow sheds at admission
///    (`QueueFull`), admitted-but-stale work expires at batch formation
///    (`DeadlineExceeded`), and everything still resolves.
///
/// Reported: goodput (answered req/s), shed rate, and latency p50/p99 of
/// the *accepted* requests — the metric load shedding exists to protect.
fn overload_section(model: &intreeger::ir::Model, ds: &intreeger::data::Dataset) {
    section("overload: open-loop flood at >= 2x capacity (admission control + TTL)");
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let config = ServerConfig {
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) },
        queue_depth: 256,
        n_workers: 1,
        ..Default::default()
    };

    // 1. Closed-loop capacity probe.
    let probe_n = if smoke { 1_000 } else { 4_000 };
    let server = InferenceServer::start(model, None, config.clone());
    let reqs: Vec<Vec<f32>> = (0..probe_n).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
    let t0 = Instant::now();
    let answered = server.infer_many(reqs).iter().filter(|r| r.is_ok()).count();
    let capacity = answered as f64 / t0.elapsed().as_secs_f64();
    drop(server);
    println!("capacity (closed loop, queue 256): {capacity:>8.0} req/s");

    // 2. Open-loop flood with a per-request TTL.
    let offered = if smoke { 2_000 } else { 8_000 };
    let ttl = Duration::from_millis(5);
    let server = InferenceServer::start(model, None, config);
    let mut rxs = Vec::with_capacity(offered);
    let mut shed = 0u64;
    let t0 = Instant::now();
    for i in 0..offered {
        match server.submit_with_ttl(ds.row(i % ds.n_rows()).to_vec(), Some(ttl)) {
            Ok(rx) => rxs.push(rx),
            Err(ServeError::QueueFull) => shed += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    let submit_wall = t0.elapsed().as_secs_f64();
    let (mut ok, mut expired, mut lost) = (0u64, 0u64, 0u64);
    for rx in rxs {
        match rx.recv().unwrap_or(Err(ServeError::WorkerLost)) {
            Ok(_) => ok += 1,
            Err(ServeError::DeadlineExceeded) => expired += 1,
            Err(_) => lost += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    let offered_rate = offered as f64 / submit_wall;
    let goodput = ok as f64 / wall;
    let shed_rate = shed as f64 / offered as f64;
    assert_eq!(ok + expired + lost + shed, offered as u64, "every request resolves");
    println!(
        "offered {offered} req at {offered_rate:>8.0} req/s ({:.1}x capacity)",
        offered_rate / capacity.max(1.0)
    );
    println!(
        "goodput {goodput:>8.0} req/s  shed rate {:.1}% ({shed})  expired {expired}  lost {lost}",
        shed_rate * 100.0
    );
    println!(
        "accepted-request latency: p50 {:.0} us  p99 {:.0} us (admitted work keeps its latency)",
        snap.latency_p50_us,
        snap.latency_p99_us
    );

    // Poisson saturation sweep (schema 2): open-loop arrivals at fixed
    // fractions/multiples of the measured capacity.
    let saturation = poisson_saturation(model, ds, capacity, smoke);

    // Admission path comparison (schema 3): clone vs slab hot path.
    let admission = admission_section(model, ds, smoke);

    // Machine-readable artifact, BENCH_batch.json-style.
    let path = std::env::var("INTREEGER_SERVE_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json").to_string()
    });
    let doc = obj(vec![
        ("bench", s("serve_throughput")),
        ("schema", num(3.0)),
        ("note", s("overload study + Poisson saturation curve + admission path comparison; regenerate with: cargo bench --bench serve_throughput")),
        ("pending", Json::Bool(false)),
        ("smoke", Json::Bool(smoke)),
        ("capacity_req_s", num(capacity)),
        ("offered_req_s", num(offered_rate)),
        ("goodput_req_s", num(goodput)),
        ("shed_rate", num(shed_rate)),
        ("accepted_p50_us", num(snap.latency_p50_us)),
        ("accepted_p99_us", num(snap.latency_p99_us)),
        (
            "counters",
            obj(vec![
                ("offered", num(offered as f64)),
                ("ok", num(ok as f64)),
                ("shed", num(shed as f64)),
                ("expired", num(expired as f64)),
                ("lost", num(lost as f64)),
            ]),
        ),
        ("saturation", saturation),
        ("admission", admission),
    ]);
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// ISSUE-8 Poisson saturation sweep. Open-loop arrivals: inter-arrival
/// gaps are drawn from a **deterministic seeded** exponential sampler
/// (`dt = -ln(1-u)/lambda`, SplitMix64 underneath — the same schedule
/// at every run), paced in real time, with each request carrying a 5 ms
/// TTL. One fresh server per point so the per-point metrics (accepted
/// p50/p99) are not contaminated across loads. Returns the
/// machine-readable `saturation` array, sorted by measured offered
/// rate, with `ok + shed + expired + lost == offered` asserted at every
/// point.
fn poisson_saturation(
    model: &intreeger::ir::Model,
    ds: &intreeger::data::Dataset,
    capacity: f64,
    smoke: bool,
) -> Json {
    section("Poisson saturation curve: open-loop arrivals at fractions of capacity");
    let multiples = [0.5f64, 0.9, 1.2, 2.0];
    let per_point = if smoke { 1_500 } else { 10_000 };
    let ttl = Duration::from_millis(5);
    let mut rng = Rng::new(0x9e3779b97f4a7c15);
    let mut points: Vec<(f64, Json)> = Vec::new();

    for (k, &mult) in multiples.iter().enumerate() {
        let lambda = (capacity * mult).max(1.0); // arrivals per second
        let config = ServerConfig {
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(200) },
            queue_depth: 256,
            n_workers: 1,
            ..Default::default()
        };
        let server = InferenceServer::start(model, None, config);

        // Deterministic arrival schedule (seconds since t0), drawn
        // before the clock starts so sampling cost never shapes load.
        let mut point_rng = rng.fork(k as u64);
        let mut schedule = Vec::with_capacity(per_point);
        let mut t = 0.0f64;
        for _ in 0..per_point {
            let u = point_rng.uniform();
            t += -(1.0 - u).ln() / lambda;
            schedule.push(t);
        }
        // Rows pre-cloned so the pacing loop does no allocation beyond
        // the handoff the coordinator requires anyway.
        let mut rows: Vec<Vec<f32>> =
            (0..per_point).map(|i| ds.row(i % ds.n_rows()).to_vec()).collect();
        rows.reverse(); // pop() yields them in order

        let mut rxs = Vec::with_capacity(per_point);
        let mut shed = 0u64;
        let t0 = Instant::now();
        for &due in &schedule {
            // Hybrid pacing: coarse sleep to ~200 us out, then spin —
            // sleep granularity would otherwise flatten the high-rate
            // points into a burst train.
            loop {
                let now = t0.elapsed().as_secs_f64();
                let remaining = due - now;
                if remaining <= 0.0 {
                    break;
                }
                if remaining > 200e-6 {
                    std::thread::sleep(Duration::from_secs_f64(remaining - 150e-6));
                }
            }
            match server.submit_with_ttl(rows.pop().expect("row per arrival"), Some(ttl)) {
                Ok(rx) => rxs.push(rx),
                Err(ServeError::QueueFull) => shed += 1,
                Err(e) => panic!("unexpected admission error: {e}"),
            }
        }
        let submit_wall = t0.elapsed().as_secs_f64();
        let (mut ok, mut expired, mut lost) = (0u64, 0u64, 0u64);
        for rx in rxs {
            match rx.recv().unwrap_or(Err(ServeError::WorkerLost)) {
                Ok(_) => ok += 1,
                Err(ServeError::DeadlineExceeded) => expired += 1,
                Err(_) => lost += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics();
        let offered_rate = per_point as f64 / submit_wall;
        let goodput = ok as f64 / wall;
        let shed_rate = shed as f64 / per_point as f64;
        assert_eq!(
            ok + shed + expired + lost,
            per_point as u64,
            "saturation point {mult}x: every request resolves"
        );
        println!(
            "{mult:>4.1}x capacity: offered {offered_rate:>8.0} req/s  goodput {goodput:>8.0} req/s  \
             shed {:>5.1}%  expired {expired:>5}  accepted p50 {:>6.0} us  p99 {:>7.0} us",
            shed_rate * 100.0,
            snap.latency_p50_us,
            snap.latency_p99_us
        );
        points.push((
            offered_rate,
            obj(vec![
                ("offered_mult", num(mult)),
                ("offered_req_s", num(offered_rate)),
                ("goodput_req_s", num(goodput)),
                ("shed_rate", num(shed_rate)),
                ("accepted_p50_us", num(snap.latency_p50_us)),
                ("accepted_p99_us", num(snap.latency_p99_us)),
                (
                    "counters",
                    obj(vec![
                        ("offered", num(per_point as f64)),
                        ("ok", num(ok as f64)),
                        ("shed", num(shed as f64)),
                        ("expired", num(expired as f64)),
                        ("lost", num(lost as f64)),
                    ]),
                ),
            ]),
        ));
    }
    // Sorted by measured offered rate so the artifact reads as a curve
    // (and the CI validator can assert monotonicity directly).
    points.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    arr(points.into_iter().map(|(_, p)| p))
}

/// ISSUE-10 admission path comparison. The same closed-loop workload
/// (submit, wait for the reply, repeat — queueing excluded so the delta
/// is pure admission cost) through both front doors:
///
/// * **clone** — `submit(Vec<f32>)`: a heap copy of the feature row and
///   a fresh reply channel per request (the pre-slab path, still the
///   right call for callers who already own a `Vec`);
/// * **slab** — `checkout_row` + `copy_from` + `submit_pooled` with one
///   reused [`ReplySlot`]: features land in the arena, the reply reuses
///   the slot's channel and recycled payload `Vec` — zero allocations
///   per request in steady state (the counting-allocator test in
///   `tests/http_corpus.rs` proves that claim; this section prices it).
///
/// Returns the machine-readable `admission` object for `BENCH_serve.json`.
fn admission_section(model: &intreeger::ir::Model, ds: &intreeger::data::Dataset, smoke: bool) -> Json {
    section("admission path: clone-per-request vs slab checkout (closed loop)");
    let n = if smoke { 2_000usize } else { 10_000 };
    let config = ServerConfig {
        policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_micros(100) },
        n_workers: 1,
        ..Default::default()
    };

    let server = InferenceServer::start(model, None, config.clone());
    let t0 = Instant::now();
    for i in 0..n {
        let rx = server.submit(ds.row(i % ds.n_rows()).to_vec()).expect("clone submit");
        let resp = rx.recv().unwrap_or(Err(ServeError::WorkerLost)).expect("clone reply");
        black_box(resp.class);
    }
    let clone_rate = n as f64 / t0.elapsed().as_secs_f64();
    drop(server);

    let server = InferenceServer::start(model, None, config);
    let mut slot = ReplySlot::new();
    let t0 = Instant::now();
    for i in 0..n {
        let mut row = server.checkout_row().expect("slab row");
        row.copy_from(ds.row(i % ds.n_rows()));
        server.submit_pooled(row, &mut slot).expect("pooled submit");
        let resp = slot.recv().expect("pooled reply");
        black_box(resp.class);
        slot.recycle(resp.fixed);
    }
    let slab_rate = n as f64 / t0.elapsed().as_secs_f64();
    drop(server);

    let ratio = slab_rate / clone_rate.max(1.0);
    println!("clone submit:   {clone_rate:>8.0} req/s (heap Vec + fresh channel per request)");
    println!("slab  submit:   {slab_rate:>8.0} req/s (arena row + reused ReplySlot, zero alloc)");
    println!("slab vs clone:  {ratio:.2}x");
    obj(vec![
        ("requests_per_leg", num(n as f64)),
        ("clone_req_s", num(clone_rate)),
        ("slab_req_s", num(slab_rate)),
        ("slab_vs_clone", num(ratio)),
    ])
}
