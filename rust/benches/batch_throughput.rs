//! E10 — batch-first execution core: branchy vs predicated-branchless
//! tiled kernels vs the per-row scalar engines, swept over batch size ×
//! variant × node layout.
//!
//! Acceptance targets:
//! * ISSUE 1: at batch ≥ 64 on the shuttle-like model, the tiled kernel
//!   delivers ≥ 2x rows/sec over the per-row baseline of the same
//!   variant.
//! * ISSUE 2: at batch ≥ 256 on the shuttle-like model (integer
//!   variants), the branchless fixed-trip kernel delivers ≥ 1.5x
//!   rows/sec over the PR-1 branchy tiled kernel.
//!
//! Besides the human-readable table, every cell is appended to a
//! machine-readable **`BENCH_batch.json`** at the repository root (path
//! overridable via `INTREEGER_BENCH_JSON`) so the perf trajectory is
//! tracked across PRs. Counts come from `BenchOpts::from_env()`
//! (`INTREEGER_BENCH_WARMUP` / `INTREEGER_BENCH_REPS`); headline numbers
//! are min-of-k.

use intreeger::data::{esa_like, shuttle_like};
use intreeger::inference::{
    compile_variant_with, Engine, IntEngine, NodeOrder, TraversalKernel, Variant,
};
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure_opts, report, section, BenchOpts, Measurement};
use intreeger::util::json::{arr, num, obj, s, Json};

/// One row of the machine-readable output (serialized via the crate's
/// own `util::json` writer — same machinery as the model files).
struct Cell {
    section: &'static str,
    variant: String,
    layout: String,
    kernel: String,
    batch: usize,
    m: Measurement,
}

impl Cell {
    fn to_json(&self) -> Json {
        obj(vec![
            ("section", s(self.section)),
            ("variant", s(&self.variant)),
            ("layout", s(&self.layout)),
            ("kernel", s(&self.kernel)),
            ("batch", num(self.batch as f64)),
            ("per_item_ns_min", num(self.m.per_item_ns())),
            ("per_item_ns_median", num(self.m.per_item_ns_median())),
            ("rows_per_s", num(self.m.throughput_per_s())),
        ])
    }
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut cells: Vec<Cell> = Vec::new();

    let ds = shuttle_like(12_000, 7);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        19,
    );

    section("tiled kernels vs per-row, by batch size x variant x layout (shuttle-like)");
    println!(
        "{:<10} {:<8} {:>6} {:>12} {:>12} {:>12} {:>8} {:>8}",
        "variant", "layout", "batch", "per-row ns", "branchy ns", "brless ns", "b/row", "bl/by"
    );
    // Acceptance cells: ISSUE 1 (tiled >= 2x per-row at batch >= 64) and
    // ISSUE 2 (branchless >= 1.5x branchy at batch >= 256, int variants).
    let mut accept_tiled: Vec<(String, f64)> = Vec::new();
    let mut acceptance: Vec<(String, f64)> = Vec::new();
    for variant in Variant::all() {
        for order in NodeOrder::all() {
            let mut engine = compile_variant_with(&model, variant, order);
            for batch in [1usize, 8, 64, 256, 1024] {
                let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
                let per_row = measure_opts(opts, batch as u64, || {
                    let mut acc = 0u32;
                    for r in flat.chunks_exact(ds.n_features) {
                        acc ^= engine.predict(r);
                    }
                    black_box(acc);
                });
                let mut kernel_ns = [0.0f64; 2];
                for (ki, kernel) in TraversalKernel::all().into_iter().enumerate() {
                    engine.set_kernel(kernel);
                    let m = measure_opts(opts, batch as u64, || {
                        let out = engine.predict_batch(&flat);
                        black_box(out[0]);
                    });
                    kernel_ns[ki] = m.per_item_ns();
                    cells.push(Cell {
                        section: "rf_predict_batch",
                        variant: variant.name().into(),
                        layout: order.name().into(),
                        kernel: kernel.name().into(),
                        batch,
                        m,
                    });
                }
                cells.push(Cell {
                    section: "rf_per_row",
                    variant: variant.name().into(),
                    layout: order.name().into(),
                    kernel: "per-row".into(),
                    batch,
                    m: per_row,
                });
                let [branchy_ns, branchless_ns] = kernel_ns;
                println!(
                    "{:<10} {:<8} {:>6} {:>12.1} {:>12.1} {:>12.1} {:>7.2}x {:>7.2}x",
                    variant.name(),
                    order.name(),
                    batch,
                    per_row.per_item_ns(),
                    branchy_ns,
                    branchless_ns,
                    per_row.per_item_ns() / branchless_ns,
                    branchy_ns / branchless_ns
                );
                if batch >= 64 {
                    accept_tiled.push((
                        format!("{}/{}/batch{}", variant.name(), order.name(), batch),
                        per_row.per_item_ns() / branchy_ns.min(branchless_ns),
                    ));
                }
                if batch >= 256 && variant != Variant::Float {
                    acceptance.push((
                        format!("{}/{}/batch{}", variant.name(), order.name(), batch),
                        branchy_ns / branchless_ns,
                    ));
                }
            }
        }
    }

    section("wide rows (esa-like, 87 features): integer variant, both kernels");
    let esa = esa_like(4_000, 11);
    let esa_model = RandomForest::train(
        &esa,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        23,
    );
    let mut engine = compile_variant_with(&esa_model, Variant::IntTreeger, NodeOrder::Breadth);
    for batch in [64usize, 1024] {
        let flat: Vec<f32> = esa.features[..batch * esa.n_features].to_vec();
        for kernel in TraversalKernel::all() {
            engine.set_kernel(kernel);
            let m = measure_opts(opts, batch as u64, || {
                let out = engine.predict_batch(&flat);
                black_box(out[0]);
            });
            report(&format!("esa/int/breadth/{}/batch{batch}", kernel.name()), &m);
            cells.push(Cell {
                section: "esa_wide",
                variant: "intreeger".into(),
                layout: "breadth".into(),
                kernel: kernel.name().into(),
                batch,
                m,
            });
        }
    }

    section("fixed-point serving path (predict_fixed_batch, the coordinator hot path)");
    let mut int_engine = IntEngine::compile(&model);
    for batch in [64usize, 256] {
        let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
        for kernel in TraversalKernel::all() {
            int_engine.set_kernel(kernel);
            let m = measure_opts(opts, batch as u64, || {
                let out = int_engine.predict_fixed_batch(&flat);
                black_box(out[0][0]);
            });
            report(&format!("int/predict_fixed_batch/{}/batch{batch}", kernel.name()), &m);
            cells.push(Cell {
                section: "serving_fixed",
                variant: "intreeger".into(),
                layout: "depth".into(),
                kernel: kernel.name().into(),
                batch,
                m,
            });
        }
    }

    section("acceptance: tiled kernel vs per-row (batch >= 64, target >= 2x)");
    for (name, speedup) in &accept_tiled {
        println!(
            "{name:<40} {speedup:>6.2}x {}",
            if *speedup >= 2.0 { "PASS (>= 2x)" } else { "below 2x target" }
        );
    }

    section("acceptance: branchless vs branchy (integer variants, batch >= 256, target >= 1.5x)");
    for (name, speedup) in &acceptance {
        println!(
            "{name:<40} {speedup:>6.2}x {}",
            if *speedup >= 1.5 { "PASS (>= 1.5x)" } else { "below 1.5x target" }
        );
    }

    write_json(&cells, opts);
}

fn write_json(cells: &[Cell], opts: BenchOpts) {
    let path = std::env::var("INTREEGER_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_batch.json").to_string()
    });
    let doc = obj(vec![
        ("bench", s("batch_throughput")),
        ("schema", num(1.0)),
        ("note", s("min-of-k timings; regenerate with: cargo bench --bench batch_throughput")),
        (
            "opts",
            obj(vec![
                ("warmup", num(opts.warmup as f64)),
                ("reps", num(opts.reps as f64)),
            ]),
        ),
        ("rows", arr(cells.iter().map(Cell::to_json))),
    ]);
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!("\nwrote {} ({} cells)", path, cells.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
