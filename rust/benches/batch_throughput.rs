//! E10 — batch-first execution core: tiled traversal kernel vs the
//! per-row scalar engines, swept over batch size × variant × node
//! layout.
//!
//! Acceptance target (ISSUE 1): at batch ≥ 64 on the shuttle-like
//! model, the tiled kernel delivers ≥ 2x rows/sec over the per-row
//! baseline of the same variant. The sweep prints the speedup per cell
//! so regressions are visible at a glance.

use intreeger::data::{esa_like, shuttle_like};
use intreeger::inference::{compile_variant_with, Engine, NodeOrder, Variant};
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure, report, section};

fn main() {
    let ds = shuttle_like(12_000, 7);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        19,
    );

    section("tiled batch kernel vs per-row, by batch size x variant x layout (shuttle-like)");
    println!(
        "{:<10} {:<8} {:>6} {:>14} {:>14} {:>9}",
        "variant", "layout", "batch", "per-row ns", "batched ns", "speedup"
    );
    for variant in Variant::all() {
        for order in NodeOrder::all() {
            let engine = compile_variant_with(&model, variant, order);
            for batch in [1usize, 8, 64, 256, 1024] {
                let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
                let scalar_ns = {
                    let m = measure(2, 7, batch as u64, || {
                        let mut acc = 0u32;
                        for r in flat.chunks_exact(ds.n_features) {
                            acc ^= engine.predict(r);
                        }
                        black_box(acc);
                    });
                    m.per_item_ns()
                };
                let batched_ns = {
                    let m = measure(2, 7, batch as u64, || {
                        let out = engine.predict_batch(&flat);
                        black_box(out[0]);
                    });
                    m.per_item_ns()
                };
                println!(
                    "{:<10} {:<8} {:>6} {:>14.1} {:>14.1} {:>8.2}x",
                    variant.name(),
                    order.name(),
                    batch,
                    scalar_ns,
                    batched_ns,
                    scalar_ns / batched_ns
                );
            }
        }
    }

    section("wide rows (esa-like, 87 features): integer variant");
    let esa = esa_like(4_000, 11);
    let esa_model = RandomForest::train(
        &esa,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        23,
    );
    let engine = compile_variant_with(&esa_model, Variant::IntTreeger, NodeOrder::Breadth);
    for batch in [64usize, 1024] {
        let flat: Vec<f32> = esa.features[..batch * esa.n_features].to_vec();
        let m = measure(2, 5, batch as u64, || {
            let out = engine.predict_batch(&flat);
            black_box(out[0]);
        });
        report(&format!("esa/int/breadth/batch{batch}"), &m);
    }

    section("fixed-point serving path (predict_fixed_batch, the coordinator hot path)");
    let int_engine = intreeger::inference::IntEngine::compile(&model);
    for batch in [64usize, 256] {
        let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
        let m = measure(2, 7, batch as u64, || {
            let out = int_engine.predict_fixed_batch(&flat);
            black_box(out[0][0]);
        });
        report(&format!("int/predict_fixed_batch/batch{batch}"), &m);
    }
}
