//! E10 — batch-first execution core: branchy vs predicated-branchless
//! vs QuickScorer-bitvector kernels vs the per-row scalar engines,
//! swept over batch size × variant × node layout × **SIMD backend**
//! (scalar vs runtime-detected AVX2/NEON intrinsics).
//!
//! Acceptance targets:
//! * ISSUE 1: at batch ≥ 64 on the shuttle-like model, the tiled kernel
//!   delivers ≥ 2x rows/sec over the per-row baseline of the same
//!   variant.
//! * ISSUE 2: at batch ≥ 256 on the shuttle-like model (integer
//!   variants), the branchless fixed-trip kernel delivers ≥ 1.5x
//!   rows/sec over the PR-1 branchy tiled kernel.
//! * ISSUE 3: at batch ≥ 256 on QS-eligible models (every tree ≤ 64
//!   leaves; integer variants), the QuickScorer kernel delivers ≥ 1.3x
//!   rows/sec over the branchless walker.
//! * ISSUE 5: at batch ≥ 256 (integer variants), AVX2 branchless
//!   delivers ≥ 1.3x rows/sec over scalar-backend branchless (rows
//!   emitted only on hosts where AVX2 was detected; NEON analog on
//!   aarch64).
//! * ISSUE 6: at batch ≥ 4096 (integer variant, best backend), 2
//!   intra-batch threads deliver ≥ 1.6x rows/sec over 1 thread (the
//!   `scaling` section; cells emitted only on hosts with ≥ 2 logical
//!   cores — single-core hosts record a 1-thread curve with no gate).
//!
//! Besides the human-readable table, every cell is appended to a
//! machine-readable **`BENCH_batch.json`** at the repository root (path
//! overridable via `INTREEGER_BENCH_JSON`) so the perf trajectory is
//! tracked across PRs; schema 4 tags every row with its backend, records
//! the host's `detected_features`, carries the intra-batch thread
//! `scaling` curve (rows/sec, speedup vs 1 thread and efficiency =
//! speedup/threads per swept thread count), and the `"acceptance"` array
//! carries every speedup cell with its target and pass flag (CI asserts
//! the sections exist). Counts come from `BenchOpts::from_env()`
//! (`INTREEGER_BENCH_WARMUP` / `INTREEGER_BENCH_REPS`); headline numbers
//! are min-of-k. Set **`BENCH_SMOKE=1`** for the reduced-rep CI mode
//! (tiny rep counts, two batch sizes, auxiliary sections skipped — the
//! JSON schema, scaling and acceptance sections are identical).

use intreeger::data::{esa_like, shuttle_like};
use intreeger::inference::{
    compile_variant_with, Engine, IntEngine, NodeOrder, SimdBackend, TraversalKernel, Variant,
};
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure_opts, report, section, BenchOpts, Measurement};
use intreeger::util::json::{arr, num, obj, s, Json};

/// One row of the machine-readable output (serialized via the crate's
/// own `util::json` writer — same machinery as the model files).
struct Cell {
    section: &'static str,
    variant: String,
    layout: String,
    kernel: String,
    backend: String,
    batch: usize,
    m: Measurement,
}

impl Cell {
    fn to_json(&self) -> Json {
        obj(vec![
            ("section", s(self.section)),
            ("variant", s(&self.variant)),
            ("layout", s(&self.layout)),
            ("kernel", s(&self.kernel)),
            ("backend", s(&self.backend)),
            ("batch", num(self.batch as f64)),
            ("per_item_ns_min", num(self.m.per_item_ns())),
            ("per_item_ns_median", num(self.m.per_item_ns_median())),
            ("rows_per_s", num(self.m.throughput_per_s())),
        ])
    }
}

/// One point of the intra-batch scaling curve (ISSUE 6): the integer
/// serving path at a many-tile batch on the best backend, per swept
/// thread count and kernel.
struct ScalePoint {
    kernel: String,
    backend: String,
    batch: usize,
    threads: usize,
    rows_per_s: f64,
    speedup_vs_1t: f64,
    efficiency: f64,
}

impl ScalePoint {
    fn to_json(&self) -> Json {
        obj(vec![
            ("kernel", s(&self.kernel)),
            ("backend", s(&self.backend)),
            ("batch", num(self.batch as f64)),
            ("threads", num(self.threads as f64)),
            ("rows_per_s", num(self.rows_per_s)),
            ("speedup_vs_1t", num(self.speedup_vs_1t)),
            ("efficiency", num(self.efficiency)),
        ])
    }
}

/// One acceptance cell: a named speedup against a target.
struct Accept {
    section: &'static str,
    name: String,
    speedup: f64,
    target: f64,
}

impl Accept {
    fn pass(&self) -> bool {
        self.speedup >= self.target
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("section", s(self.section)),
            ("name", s(&self.name)),
            ("speedup", num(self.speedup)),
            ("target", num(self.target)),
            ("pass", Json::Bool(self.pass())),
        ])
    }
}

fn print_acceptance(title: &str, cells: &[&Accept]) {
    section(title);
    if cells.is_empty() {
        println!("(no cells on this host)");
    }
    for a in cells {
        println!(
            "{:<52} {:>6.2}x {}",
            a.name,
            a.speedup,
            if a.pass() {
                format!("PASS (>= {:.1}x)", a.target)
            } else {
                format!("below {:.1}x target", a.target)
            }
        );
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let opts = if smoke {
        println!("BENCH_SMOKE=1: reduced-rep smoke mode");
        BenchOpts { warmup: 1, reps: 3 }
    } else {
        BenchOpts::from_env()
    };
    // `sweep()`, not `available()`: an `INTREEGER_BACKEND` pin collapses
    // the bench to that backend, same as every engine in the process
    // (profiling the fallback path is exactly when you want that).
    let backends: Vec<SimdBackend> = SimdBackend::sweep();
    let best = *backends.last().expect("sweep is never empty");
    let scalar_baseline = backends[0] == SimdBackend::Scalar;
    println!(
        "host SIMD features: [{}]; backends swept: [{}]",
        SimdBackend::detected_features().join(", "),
        backends.iter().map(|b| b.name()).collect::<Vec<_>>().join(", ")
    );
    let mut cells: Vec<Cell> = Vec::new();
    let mut accepts: Vec<Accept> = Vec::new();

    let ds = shuttle_like(if smoke { 5_000 } else { 12_000 }, 7);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
        19,
    );
    // QS acceptance is only meaningful on models the bitvector kernel
    // fully covers (depth-6 trees always are; asserted, not assumed).
    let qs_eligible = intreeger::ir::stats::stats(&model).qs_ineligible.is_empty();
    assert!(qs_eligible, "the shuttle bench model must be QS-eligible");

    let kernels = TraversalKernel::all();
    section("kernels x backends vs per-row, by batch size x variant x layout (shuttle-like)");
    println!(
        "{:<10} {:<8} {:<7} {:>6} {:>11} {:>11} {:>11} {:>11} {:>7} {:>7} {:>7}",
        "variant", "layout", "backend", "batch", "per-row ns", "branchy ns", "brless ns",
        "qs ns", "pr/bl", "bl/by", "qs/bl"
    );
    let batches: &[usize] = if smoke { &[8, 256] } else { &[1, 8, 64, 256, 1024] };
    for variant in Variant::all() {
        for order in NodeOrder::all() {
            let mut engine = compile_variant_with(&model, variant, order);
            for &batch in batches {
                let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
                let per_row = measure_opts(opts, batch as u64, || {
                    let mut acc = 0u32;
                    for r in flat.chunks_exact(ds.n_features) {
                        acc ^= engine.predict(r);
                    }
                    black_box(acc);
                });
                cells.push(Cell {
                    section: "rf_per_row",
                    variant: variant.name().into(),
                    layout: order.name().into(),
                    kernel: "per-row".into(),
                    backend: "scalar".into(),
                    batch,
                    m: per_row,
                });
                // kernel_ns[backend index][kernel index]
                let mut kernel_ns = vec![[0.0f64; 3]; backends.len()];
                for (bi, &backend) in backends.iter().enumerate() {
                    engine.set_backend(backend);
                    for (ki, kernel) in kernels.into_iter().enumerate() {
                        engine.set_kernel(kernel);
                        let m = measure_opts(opts, batch as u64, || {
                            let out = engine.predict_batch(&flat);
                            black_box(out[0]);
                        });
                        kernel_ns[bi][ki] = m.per_item_ns();
                        cells.push(Cell {
                            section: "rf_predict_batch",
                            variant: variant.name().into(),
                            layout: order.name().into(),
                            kernel: kernel.name().into(),
                            backend: backend.name().into(),
                            batch,
                            m,
                        });
                    }
                    let [branchy_ns, branchless_ns, qs_ns] = kernel_ns[bi];
                    println!(
                        "{:<10} {:<8} {:<7} {:>6} {:>11.1} {:>11.1} {:>11.1} {:>11.1} {:>6.2}x {:>6.2}x {:>6.2}x",
                        variant.name(),
                        order.name(),
                        backend.name(),
                        batch,
                        per_row.per_item_ns(),
                        branchy_ns,
                        branchless_ns,
                        qs_ns,
                        per_row.per_item_ns() / branchless_ns,
                        branchy_ns / branchless_ns,
                        branchless_ns / qs_ns
                    );
                }
                // Scalar-backend cells carry the PR-1/2/3 acceptance
                // gates (their semantics predate the backend dimension);
                // the backend gate compares best-vs-scalar branchless.
                // Under an env pin to a non-scalar backend there is no
                // scalar baseline in the sweep, so no gates are emitted
                // (rows are still recorded).
                if !scalar_baseline {
                    continue;
                }
                let [branchy_ns, branchless_ns, qs_ns] = kernel_ns[0];
                let tag = format!("{}/{}/batch{}", variant.name(), order.name(), batch);
                if batch >= 64 {
                    // Tiled *walker* kernels only (the ISSUE-1 gate):
                    // folding qs in could mask a walker regression.
                    accepts.push(Accept {
                        section: "tiled_vs_per_row",
                        name: tag.clone(),
                        speedup: per_row.per_item_ns() / branchy_ns.min(branchless_ns),
                        target: 2.0,
                    });
                }
                if batch >= 256 && variant != Variant::Float {
                    accepts.push(Accept {
                        section: "branchless_vs_branchy",
                        name: tag.clone(),
                        speedup: branchy_ns / branchless_ns,
                        target: 1.5,
                    });
                    accepts.push(Accept {
                        section: "qs_vs_branchless",
                        name: tag.clone(),
                        speedup: branchless_ns / qs_ns,
                        target: 1.3,
                    });
                    if best != SimdBackend::Scalar {
                        // The ISSUE-5 gate: explicit lanes must beat the
                        // autovectorization hope by a measured margin.
                        let simd_branchless_ns = kernel_ns[backends.len() - 1][1];
                        accepts.push(Accept {
                            section: "simd_branchless_vs_scalar_branchless",
                            name: format!("{tag}/{}", best.name()),
                            speedup: branchless_ns / simd_branchless_ns,
                            target: 1.3,
                        });
                    }
                }
            }
        }
    }

    if !smoke {
        section("wide rows (esa-like, 87 features): integer variant, all kernels x backends");
        let esa = esa_like(4_000, 11);
        let esa_model = RandomForest::train(
            &esa,
            &ForestParams { n_trees: 10, max_depth: 6, ..Default::default() },
            23,
        );
        let mut engine = compile_variant_with(&esa_model, Variant::IntTreeger, NodeOrder::Breadth);
        for batch in [64usize, 1024] {
            let flat: Vec<f32> = esa.features[..batch * esa.n_features].to_vec();
            for &backend in &backends {
                engine.set_backend(backend);
                for kernel in kernels {
                    engine.set_kernel(kernel);
                    let m = measure_opts(opts, batch as u64, || {
                        let out = engine.predict_batch(&flat);
                        black_box(out[0]);
                    });
                    report(
                        &format!(
                            "esa/int/breadth/{}/{}/batch{batch}",
                            kernel.name(),
                            backend.name()
                        ),
                        &m,
                    );
                    cells.push(Cell {
                        section: "esa_wide",
                        variant: "intreeger".into(),
                        layout: "breadth".into(),
                        kernel: kernel.name().into(),
                        backend: backend.name().into(),
                        batch,
                        m,
                    });
                }
            }
        }

        section("fixed-point serving path (predict_fixed_batch, the coordinator hot path)");
        let mut int_engine = IntEngine::compile(&model);
        for batch in [64usize, 256] {
            let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
            for &backend in &backends {
                int_engine.set_backend(backend);
                for kernel in kernels {
                    int_engine.set_kernel(kernel);
                    let m = measure_opts(opts, batch as u64, || {
                        let out = int_engine.predict_fixed_batch(&flat);
                        black_box(out[0][0]);
                    });
                    report(
                        &format!(
                            "int/predict_fixed_batch/{}/{}/batch{batch}",
                            kernel.name(),
                            backend.name()
                        ),
                        &m,
                    );
                    cells.push(Cell {
                        section: "serving_fixed",
                        variant: "intreeger".into(),
                        layout: "depth".into(),
                        kernel: kernel.name().into(),
                        backend: backend.name().into(),
                        batch,
                        m,
                    });
                }
            }
        }
    }

    // Intra-batch thread scaling (ISSUE 6): the serving hot path
    // (`predict_fixed_batch`) at a many-tile batch on the best backend,
    // per kernel, over the same thread counts startup calibration sweeps
    // ([1, powers of two, all logical cores] — or a pinned
    // INTREEGER_THREADS). Runs in smoke mode too: CI validates the
    // section's schema on every push.
    section("intra-batch thread scaling (integer serving path, best backend, batch 4096)");
    let threads_sweep = intreeger::inference::parallel::sweep();
    println!(
        "logical cores detected: {}; thread counts swept: {threads_sweep:?}",
        intreeger::inference::parallel::detected()
    );
    let mut scaling: Vec<ScalePoint> = Vec::new();
    {
        let batch = 4096usize.min(ds.n_rows());
        let flat: Vec<f32> = ds.features[..batch * ds.n_features].to_vec();
        let mut engine = IntEngine::compile(&model);
        engine.set_backend(best);
        for kernel in kernels {
            engine.set_kernel(kernel);
            let mut base_rows_per_s = 0.0f64;
            for &threads in &threads_sweep {
                engine.set_threads(threads);
                let m = measure_opts(opts, batch as u64, || {
                    let out = engine.predict_fixed_batch(&flat);
                    black_box(out[0][0]);
                });
                let rows_per_s = m.throughput_per_s();
                // Reference = the first swept count (1 thread unless an
                // env pin collapsed the sweep).
                if base_rows_per_s == 0.0 {
                    base_rows_per_s = rows_per_s;
                }
                let speedup = rows_per_s / base_rows_per_s;
                let efficiency = speedup / threads as f64;
                println!(
                    "{:<12} {:>2} thread(s): {:>12.0} rows/s  ({:.2}x vs 1t, efficiency {:.2})",
                    kernel.name(),
                    threads,
                    rows_per_s,
                    speedup,
                    efficiency
                );
                scaling.push(ScalePoint {
                    kernel: kernel.name().into(),
                    backend: best.name().into(),
                    batch,
                    threads,
                    rows_per_s,
                    speedup_vs_1t: speedup,
                    efficiency,
                });
                // The 2-thread gate only exists where the reference really
                // was 1 thread and the host has a second core to scale to.
                if threads == 2 && threads_sweep.first() == Some(&1) {
                    accepts.push(Accept {
                        section: "scaling",
                        name: format!("int/{}/{}/batch{batch}/2t", kernel.name(), best.name()),
                        speedup,
                        target: 1.6,
                    });
                }
            }
            engine.set_threads(1);
        }
    }

    let by_section = |sec: &str| -> Vec<&Accept> {
        accepts.iter().filter(|a| a.section == sec).collect()
    };
    print_acceptance(
        "acceptance: tiled kernel vs per-row (batch >= 64, target >= 2x)",
        &by_section("tiled_vs_per_row"),
    );
    print_acceptance(
        "acceptance: branchless vs branchy (integer variants, batch >= 256, target >= 1.5x)",
        &by_section("branchless_vs_branchy"),
    );
    print_acceptance(
        "acceptance: quickscorer vs branchless (integer variants, QS-eligible, batch >= 256, target >= 1.3x)",
        &by_section("qs_vs_branchless"),
    );
    print_acceptance(
        "acceptance: SIMD branchless vs scalar branchless (integer variants, batch >= 256, target >= 1.3x)",
        &by_section("simd_branchless_vs_scalar_branchless"),
    );
    print_acceptance(
        "acceptance: 2 intra-batch threads vs 1 (integer serving path, batch 4096, target >= 1.6x)",
        &by_section("scaling"),
    );

    write_json(&cells, &scaling, &accepts, &backends, opts, smoke);
}

fn write_json(
    cells: &[Cell],
    scaling: &[ScalePoint],
    accepts: &[Accept],
    backends: &[SimdBackend],
    opts: BenchOpts,
    smoke: bool,
) {
    let path = std::env::var("INTREEGER_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_batch.json").to_string()
    });
    let doc = obj(vec![
        ("bench", s("batch_throughput")),
        ("schema", num(4.0)),
        ("note", s("min-of-k timings; regenerate with: cargo bench --bench batch_throughput")),
        (
            "detected_features",
            arr(SimdBackend::detected_features().into_iter().map(s)),
        ),
        ("backends", arr(backends.iter().map(|b| s(b.name())))),
        (
            "opts",
            obj(vec![
                ("warmup", num(opts.warmup as f64)),
                ("reps", num(opts.reps as f64)),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        ("rows", arr(cells.iter().map(Cell::to_json))),
        ("scaling", arr(scaling.iter().map(ScalePoint::to_json))),
        ("acceptance", arr(accepts.iter().map(Accept::to_json))),
    ]);
    match std::fs::write(&path, doc.to_string() + "\n") {
        Ok(()) => println!(
            "\nwrote {} ({} cells, {} scaling points, {} acceptance entries)",
            path,
            cells.len(),
            scaling.len(),
            accepts.len()
        ),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
