//! E4 — reproduces §IV-C (Listings 2–4): how each ISA materializes the
//! split-value and probability immediates, and what that does to
//! instruction counts per node.
//!
//! Shows (a) listing-style instruction sequences per ISA/variant,
//! (b) measured 20-bit-immediate (`lui`-only) fractions on a real
//! trained model, (c) per-event instruction counts from the core models.

use intreeger::data::shuttle_like;
use intreeger::flint::ordered_u32;

use intreeger::ir::Node;
use intreeger::simarch::{trace_average, Core};
use intreeger::trees::{ForestParams, RandomForest};

fn listing(isa: &str, rows: &[(&str, &str)]) {
    println!("\n  [{isa}]");
    for (ins, why) in rows {
        println!("    {:<38} # {}", ins, why);
    }
}

fn main() {
    println!("§IV-C — immediate conversion across ISAs");

    // A real threshold/probability pair for concreteness (the paper uses
    // 87.5 = 0x42af0000 and 4292021501).
    let threshold = 87.5f32;
    let tbits = threshold.to_bits();
    let tord = ordered_u32(threshold);
    let prob = 4_292_021_501u32;
    println!("\nexample split value {threshold} -> raw bits 0x{tbits:08x}, ordered 0x{tord:08x}");
    println!("example leaf immediate {prob} (0x{prob:08x})");

    println!("\nInTreeger threshold compare + leaf add, per ISA:");
    listing(
        "RISC-V (Listing 2)",
        &[
            ("lw      a4, 20(a0)", "load feature word"),
            ("lui     a5, 0x42af0", "upper 20 bits of immediate (1 instr when low 12 bits are 0)"),
            ("blt     a5, a4, .else", "integer compare + branch"),
            ("lw      a3, 0(a2)", "load result[c]"),
            ("lui     a0, 0xffd31 ; addiw a0, a0, -771", "32-bit immediate = lui + addiw"),
            ("addw    a3, a3, a0 ; sw a3, 0(a2)", "integer add + store"),
        ],
    );
    listing(
        "ARMv7 (Listing 3)",
        &[
            ("ldr     r1, [r0, #8]", "load feature word"),
            ("ldr     r3, [pc, #744]", "immediate from literal pool (no lui analogue)"),
            ("cmp     r1, r3 ; bgt .else", "integer compare + branch"),
            ("ldr     lr, [r2] ; ldr r3, [pc, #320]", "result[c] + pool immediate"),
            ("add     r3, lr, r3 ; str r3, [r2]", "integer add + store"),
        ],
    );
    listing(
        "x86-64",
        &[
            ("cmp     dword ptr [rdi+20], 0x42af0000", "immediate embedded in the compare"),
            ("jg      .else", "branch"),
            ("add     dword ptr [rsi], 0xffd30cfd", "leaf add: single RMW with imm32"),
        ],
    );
    listing(
        "float baseline (RISC-V, Listing 4)",
        &[
            ("fmv.w.x ft2, a5 ; flw fa2, 488(gp)", "move to FP file + load split value"),
            ("fle.s   a5, ft2, fa2 ; bnez a5, .else", "FP compare (latency exposed) + branch"),
            ("flw     fa4, 4(a2) ; flw fa5, 272(gp)", "FP loads for accumulate"),
            ("fadd.s  fa4, fa4, fa5 ; fsw fa4, 4(a2)", "FP add + store"),
        ],
    );

    // Measured immediate statistics on a trained model.
    let ds = shuttle_like(12_000, 3);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 50, max_depth: 7, ..Default::default() },
        3,
    );
    let tr = trace_average(&model, &ds, 200);
    println!("\nmeasured on shuttle-like RF (50 trees, depth<=7):");
    println!(
        "  thresholds fitting a single RISC-V lui (low 12 bits zero): {:.1}%",
        tr.imm20_fraction_thresholds * 100.0
    );
    println!(
        "  leaf immediates fitting a single lui:                      {:.1}%",
        tr.imm20_fraction_probs * 100.0
    );
    // Raw float thresholds always have low-12-zero mantissa tails?
    let mut lui_raw = 0usize;
    let mut total = 0usize;
    for t in &model.trees {
        for n in &t.nodes {
            if let Node::Branch { threshold, .. } = n {
                total += 1;
                if threshold.to_bits() & 0xFFF == 0 {
                    lui_raw += 1;
                }
            }
        }
    }
    println!(
        "  raw threshold bits with low 12 bits zero (FlInt's natural fit): {:.1}%",
        lui_raw as f64 / total.max(1) as f64 * 100.0
    );

    // Per-event instruction counts from the core models.
    println!("\nper-event dynamic instruction counts (core models):");
    println!(
        "{:>22} {:>14} {:>14} {:>12} {:>12}",
        "core", "branch(float)", "branch(int)", "leaf(float)", "leaf(int)"
    );
    for core in Core::application_cores() {
        let p = core.params();
        println!(
            "{:>22} {:>14.1} {:>14.1} {:>12.1} {:>12.1}",
            core.name(),
            p.i_branch_float,
            p.i_branch_int + p.i_branch_int_extra_imm * (1.0 - tr.imm20_fraction_thresholds),
            p.i_leaf_float,
            p.i_leaf_int + p.i_leaf_int_extra_imm * (1.0 - tr.imm20_fraction_probs),
        );
    }
    println!("\npaper observation reproduced: instruction counts are close across variants;");
    println!("x86/RISC-V embed immediates cheaply (cmp imm32 / lui), ARMv7 needs literal-pool loads.");
}
