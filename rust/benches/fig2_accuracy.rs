//! E1/E2 — reproduces Fig 2 + §IV-B: accuracy parity and probability
//! deltas between the float and integer-only implementations.
//!
//! Paper protocol: 75/25 split, 10 randomized splits, RF up to 100
//! trees; result: *identical predictions on every sample*, probability
//! deltas ~1e-10 for 1 tree, ~1e-8 for 100 trees (proportional to
//! n/2^32).

use intreeger::data::{esa_like, shuttle_like, Dataset};
use intreeger::inference::{Engine, FlIntEngine, FloatEngine, IntEngine};
use intreeger::quant::error_bound;
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::Rng;

fn run_dataset(name: &str, ds: &Dataset, tree_counts: &[usize], n_splits: usize) {
    println!("\n--- dataset: {name} ({} rows, {} classes) ---", ds.n_rows(), ds.n_classes);
    println!(
        "{:>7} {:>9} {:>13} {:>13} {:>13} {:>10}",
        "trees", "splits", "pred_mismatch", "max|dp|", "avg|dp|", "bound n/2^32"
    );
    for &n_trees in tree_counts {
        let mut mismatches = 0u64;
        let mut checked = 0u64;
        let mut max_dp = 0f64;
        let mut sum_dp = 0f64;
        let mut dp_count = 0u64;
        for split in 0..n_splits {
            let mut rng = Rng::new(split as u64 + 1000);
            let (train, test) = ds.train_test_split(0.25, &mut rng);
            let model = RandomForest::train(
                &train,
                &ForestParams { n_trees, max_depth: 7, ..Default::default() },
                split as u64,
            );
            let fe = FloatEngine::compile(&model);
            let fl = FlIntEngine::compile(&model);
            let ie = IntEngine::compile(&model);
            // cap evaluation rows per split for runtime
            let rows = test.n_rows().min(1500);
            for i in 0..rows {
                let row = test.row(i);
                let a = fe.predict(row);
                if a != ie.predict(row) || a != fl.predict(row) {
                    mismatches += 1;
                }
                checked += 1;
                let pf = fe.predict_proba(row);
                let pi = ie.predict_proba(row);
                for (x, y) in pf.iter().zip(&pi) {
                    let d = (*x as f64 - *y as f64).abs();
                    max_dp = max_dp.max(d);
                    sum_dp += d;
                    dp_count += 1;
                }
            }
        }
        println!(
            "{:>7} {:>9} {:>10}/{:<6} {:>13.3e} {:>13.3e} {:>10.3e}",
            n_trees,
            n_splits,
            mismatches,
            checked,
            max_dp,
            sum_dp / dp_count.max(1) as f64,
            error_bound(n_trees)
        );
        assert_eq!(mismatches, 0, "paper claim violated: predictions must be identical");
    }
}

fn main() {
    println!("Fig 2 / §IV-B — float vs integer-only: prediction parity and probability deltas");
    let shuttle = shuttle_like(12_000, 1);
    let esa = esa_like(6_000, 1);
    run_dataset("shuttle-like", &shuttle, &[1, 10, 50, 100], 10);
    run_dataset("esa-like", &esa, &[1, 10, 50, 100], 10);
    println!("\nresult: 0 prediction mismatches; deltas scale with n_trees (paper: 1e-10 @ 1 tree, ~1e-8 @ 100)");
}
