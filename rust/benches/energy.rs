//! E7 — reproduces §IV-F + Fig 5: energy consumption of float vs
//! integer-only inference on the ARMv7 device (Raspberry Pi class),
//! measured in the paper with a Joulescope JS220.
//!
//! Paper protocol: 14,500,000 inferences of a Shuttle RF (50 trees,
//! depth <= 7) under both implementations. Load power was statistically
//! identical (2.81 W), so the saving is runtime-driven:
//! T_float = 19.36 s, T_int = 7.79 s => E_saved ≈ 21.3 %.
//!
//! Here runtimes come from the ARMv7 cost model at 1.8 GHz and the power
//! profile from the synthetic Joulescope trace generator.

use intreeger::data::shuttle_like;
use intreeger::energy::{self, PowerModel};
use intreeger::inference::Variant;
use intreeger::simarch::{self, Core};
use intreeger::trees::{ForestParams, RandomForest};

fn main() {
    println!("§IV-F — energy: float vs integer-only, 14.5M inferences, ARMv7 @ 1.8 GHz");

    let ds = shuttle_like(14_500, 5);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 50, max_depth: 7, ..Default::default() },
        13,
    );

    const N_INFER: f64 = 14_500_000.0;
    let f = simarch::simulate(&model, &ds, Variant::Float, Core::CortexA72, 300);
    let i = simarch::simulate(&model, &ds, Variant::IntTreeger, Core::CortexA72, 300);
    let t_float = f.seconds() * N_INFER;
    let t_int = i.seconds() * N_INFER;
    println!("\nsimulated runtimes for {N_INFER:.0} inferences:");
    println!("  float:     {t_float:>8.2} s   (paper: 19.36 s)");
    println!("  intreeger: {t_int:>8.2} s   (paper:  7.79 s)");

    let pm = PowerModel::default();
    println!("\npower profile (synthetic Joulescope traces, Fig 5):");
    let base_trace = energy::synth_trace(&pm, 10.0, 0.0, 0.0, 1000.0, 1);
    println!("  baseline mean: {:.2} W (paper: ~1.82 W; idle floor {:.2} W with periodic background bumps)",
        energy::mean_power(&base_trace, 0.0, 10.0), pm.idle_w);
    let float_trace = energy::synth_trace(&pm, 3.0, t_float, 3.0, 200.0, 2);
    let int_trace = energy::synth_trace(&pm, 3.0, t_int, 3.0, 200.0, 3);
    println!(
        "  float-run load window mean: {:.2} W over {:.1} s  (trace energy {:.1} J)",
        energy::mean_power(&float_trace, 3.5, 2.5 + t_float),
        t_float,
        energy::trace_energy(&float_trace, 200.0)
    );
    println!(
        "  int-run   load window mean: {:.2} W over {:.1} s  (trace energy {:.1} J)",
        energy::mean_power(&int_trace, 3.5, 2.5 + t_int),
        t_int,
        energy::trace_energy(&int_trace, 200.0)
    );

    let r = energy::evaluate(t_float, t_int, &pm);
    println!("\nE_saved = 1 - (T_int*P_high + (T_float-T_int)*P_low) / (T_float*P_high)");
    println!(
        "        = 1 - ({:.2}*{:.2} + {:.2}*{:.2}) / ({:.2}*{:.2}) = {:.3}",
        t_int,
        r.p_high_w,
        t_float - t_int,
        r.p_low_w,
        t_float,
        r.p_high_w,
        r.e_saved
    );
    println!("\n  energy saved: {:.1}%   (paper: ≈21.3%)", r.e_saved * 100.0);

    // The paper's optimized-environment projection: lower baseline power
    // pushes the saving toward the pure runtime ratio.
    let r_opt = energy::e_saved(t_int, t_float, pm.load_w, 0.3);
    println!(
        "  with an optimized 0.3 W baseline: {:.1}%   (paper projects 'closer to 50%')",
        r_opt * 100.0
    );
    let r_runtime = 1.0 - t_int / t_float;
    println!("  pure runtime ratio bound:        {:.1}%", r_runtime * 100.0);

    // Sanity anchor: the paper's own numbers through our formula.
    let paper = energy::e_saved(7.79, 19.36, 2.81, 1.81);
    println!("\ncross-check with the paper's measured inputs: E_saved = {:.3} (paper: 0.213)", paper);
}
