//! Ablation of the code-generation design choices (DESIGN.md):
//!
//! * zero-leaf-add elision (`skip_zero_leaf_adds`) — tl2cgen emits all
//!   classes; gcc removes integer zero-adds anyway, so this mainly
//!   shrinks source/text;
//! * threshold encoding: the general order-preserving transform vs the
//!   paper's raw-bits form (`RawBitsNonNegative`, Listing 2) which saves
//!   the per-feature transform when inputs are provably non-negative;
//! * if-else vs native layout (also covered in `x86_measured`).
//!
//! All variants are verified for bit-exact parity before timing.

use intreeger::codegen::ifelse::{generate_ifelse_with, GenOpts};
use intreeger::codegen::{generate, CBinary, Layout};
use intreeger::data::{shuttle_like, Dataset};
use intreeger::flint::SplitEncoding;
use intreeger::inference::{IntEngine, Variant};
use intreeger::trees::{ForestParams, RandomForest};

/// Shuttle-like data shifted to be strictly non-negative (abs transform)
/// so the RawBitsNonNegative encoding is applicable.
fn nonneg_dataset() -> Dataset {
    let ds = shuttle_like(12_000, 8);
    let features = ds.features.iter().map(|v| v.abs()).collect();
    Dataset::new(features, ds.labels.clone(), ds.n_features, ds.n_classes)
}

fn main() {
    if !intreeger::codegen::compile::gcc_available() {
        println!("gcc unavailable — ablation skipped");
        return;
    }
    let ds = nonneg_dataset();
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 50, max_depth: 7, ..Default::default() },
        23,
    );
    let engine = IntEngine::compile(&model);
    let n_rows = 2000;
    let rows: Vec<f32> = ds.features[..n_rows * ds.n_features].to_vec();

    println!("codegen ablation — integer-only variant, shuttle-like (non-negative), 50 trees\n");
    let cases: Vec<(&str, String)> = vec![
        (
            "ifelse/ordered (default)",
            generate_ifelse_with(&model, Variant::IntTreeger, GenOpts::default()),
        ),
        (
            "ifelse/ordered+skip-zero",
            generate_ifelse_with(
                &model,
                Variant::IntTreeger,
                GenOpts { skip_zero_leaf_adds: true, ..Default::default() },
            ),
        ),
        (
            "ifelse/raw-bits (paper Listing 2)",
            generate_ifelse_with(
                &model,
                Variant::IntTreeger,
                GenOpts { encoding: SplitEncoding::RawBitsNonNegative, ..Default::default() },
            ),
        ),
        ("native/ordered", generate(&model, Layout::Native, Variant::IntTreeger)),
    ];

    println!(
        "{:<36} {:>10} {:>12} {:>12}",
        "configuration", "src bytes", "text bytes", "ns/inference"
    );
    for (name, src) in &cases {
        let bin = CBinary::compile(src, Variant::IntTreeger, ds.n_features, ds.n_classes, "abl")
            .expect("gcc compile");
        // parity first
        let got = bin.predict_u32(&rows[..64 * ds.n_features]).expect("run");
        for (i, fixed) in got.iter().enumerate() {
            assert_eq!(fixed, &engine.predict_fixed(ds.row(i)), "{name} row {i}");
        }
        let ns = bin.bench_ns(&rows, 40).expect("bench");
        println!(
            "{:<36} {:>10} {:>12} {:>12.1}",
            name,
            src.len(),
            bin.text_size.map(|s| s.to_string()).unwrap_or_else(|| "?".into()),
            ns
        );
    }
    println!("\nnotes: raw-bits saves the per-feature transform (valid only for non-negative");
    println!("inputs — the generator enforces non-negative thresholds); zero-add elision");
    println!("shrinks source with no semantic change; native trades text for data+loop.");
}
