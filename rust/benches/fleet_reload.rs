//! Fleet benchmarks (EXPERIMENTS.md E14): what the binary model format
//! and the versioned registry buy at deployment time.
//!
//! * **Load latency** — JSON parse + engine compile vs INTB
//!   validate-and-cast (the zero-copy view) vs INTB engine
//!   materialization. The binary path's headline is that validation is
//!   bounds arithmetic, not per-node deserialization.
//! * **File load path** — `FileBin::open` (mmap(2) on unix, owned-copy
//!   fallback elsewhere) vs an explicit read-into-heap + validate. The
//!   delta is the byte copy the mapped path never pays; the fleet
//!   section's RSS line below shows the residency side of the same coin.
//! * **Hot-swap latency** — publishing a pre-started server over a live
//!   registry, including the drain of the displaced version (the
//!   operator-visible "reload" cost).
//! * **Routing overhead** — an unpinned registry resolve (read lock +
//!   `Arc` clone) per request.
//! * **Steady-state fleet** — `FleetLoader` over a directory of N
//!   binary artifacts: cold load, unchanged-rescan cost, tracked bytes,
//!   and (on Linux) the process RSS with all N models resident.
//!
//! Tunables: `INTREEGER_BENCH_WARMUP` / `INTREEGER_BENCH_REPS` (shared
//! bench harness) and `INTREEGER_FLEET_MODELS` (fleet size, default 32).

use intreeger::coordinator::{
    FaultPlan, FleetLoader, InferenceServer, Metrics, ModelRegistry, ServerConfig,
};
use intreeger::data::shuttle_like;
use intreeger::inference::IntEngine;
use intreeger::ir::Model;
use intreeger::runtime::binfmt::{self, FileBin, OwnedBin};
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure_opts, report, section, BenchOpts};
use std::sync::Arc;

/// Faults pinned off so a CI-wide `INTREEGER_FAULTS` can't skew timings.
fn quiet() -> ServerConfig {
    ServerConfig { faults: Some(FaultPlan::none()), ..Default::default() }
}

fn main() {
    let opts = BenchOpts::from_env();
    let n_models: usize = std::env::var("INTREEGER_FLEET_MODELS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);

    let ds = shuttle_like(4000, 71);
    let model = RandomForest::train(
        &ds,
        &ForestParams { n_trees: 30, max_depth: 8, ..Default::default() },
        71,
    );
    let json = model.to_json();
    let engine = IntEngine::compile(&model);
    let bin = binfmt::write_forest(engine.forest());
    println!(
        "model: {} trees, JSON {} bytes, INTB {} bytes",
        model.trees.len(),
        json.len(),
        bin.len()
    );

    section("model load: JSON parse+compile vs INTB validate+cast");
    let m = measure_opts(opts, 1, || {
        let m = Model::from_json(black_box(&json)).expect("json");
        black_box(IntEngine::compile(&m));
    });
    report("load/json_parse_and_compile", &m);
    let owned = OwnedBin::from_bytes(&bin);
    let m = measure_opts(opts, 1, || {
        // The full zero-copy gate: header, section table, structural
        // validation — no engine yet.
        black_box(owned.view().expect("validate").resident_bytes());
    });
    report("load/intb_validate_only", &m);
    let m = measure_opts(opts, 1, || {
        let v = owned.view().expect("validate");
        black_box(IntEngine::from_forest(v.to_forest().expect("materialize")));
    });
    report("load/intb_validate_and_engine", &m);

    section("file load path: mmap(2) vs owned copy (FileBin)");
    let bin_path = std::env::temp_dir().join(format!("intreeger_filebin_bench_{}.bin", std::process::id()));
    std::fs::write(&bin_path, &bin).expect("write bench artifact");
    let first = FileBin::open(&bin_path).expect("open artifact");
    println!(
        "FileBin source on this platform: {} ({} bytes)",
        first.source(),
        first.bytes().len()
    );
    drop(first);
    let m = measure_opts(opts, 1, || {
        // The serving-path load: mmap(2) the artifact (owned-copy
        // fallback off unix), then run the full zero-copy validation.
        let f = FileBin::open(black_box(&bin_path)).expect("open");
        black_box(f.view().expect("validate").resident_bytes());
    });
    report("load/filebin_mmap_validate", &m);
    let m = measure_opts(opts, 1, || {
        // The pre-PR-10 path: read the whole file into a heap copy,
        // then validate. The delta is the copy the mmap path never pays.
        let bytes = std::fs::read(black_box(&bin_path)).expect("read");
        let owned = OwnedBin::from_bytes(&bytes);
        black_box(owned.view().expect("validate").resident_bytes());
    });
    report("load/filebin_owned_copy_validate", &m);
    let _ = std::fs::remove_file(&bin_path);

    section("hot swap: publish + drain over a live registry");
    let registry = Arc::new(ModelRegistry::new(Arc::new(Metrics::new())));
    let total = opts.warmup + opts.reps.max(1) + 1;
    let mut pool: Vec<InferenceServer> =
        (0..total).map(|_| InferenceServer::start(&model, None, quiet())).collect();
    let mut version = 1u64;
    registry
        .publish("swap", version, bin.len() as u64, pool.pop().expect("pool"))
        .expect("seed publish");
    let m = measure_opts(opts, 1, || {
        version += 1;
        registry
            .publish("swap", version, bin.len() as u64, pool.pop().expect("pool"))
            .expect("swap publish");
    });
    report("swap/publish_and_drain_old", &m);

    section("routing overhead: unpinned resolve per request");
    let m = measure_opts(opts, 10_000, || {
        for _ in 0..10_000 {
            black_box(registry.resolve("swap", None).expect("resolve"));
        }
    });
    report("route/resolve_unpinned", &m);

    section("steady-state fleet via FleetLoader");
    let dir = std::env::temp_dir().join(format!("intreeger_fleet_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench dir");
    let small_ds = shuttle_like(600, 5);
    let small = RandomForest::train(
        &small_ds,
        &ForestParams { n_trees: 4, max_depth: 4, ..Default::default() },
        5,
    );
    let small_bin = binfmt::write_forest(IntEngine::compile(&small).forest());
    for i in 0..n_models {
        std::fs::write(dir.join(format!("model_{i:03}.bin")), &small_bin).expect("write artifact");
    }
    let loader = FleetLoader::new(
        dir.clone(),
        Arc::new(ModelRegistry::new(Arc::new(Metrics::new()))),
        quiet(),
    );
    let t0 = std::time::Instant::now();
    let cold = loader.reload().expect("cold load");
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "cold load: {} models in {cold_ms:.1} ms ({:.2} ms/model), tracked {} bytes",
        cold.loaded.len(),
        cold_ms / n_models.max(1) as f64,
        loader.registry().tracked_bytes()
    );
    let m = measure_opts(opts, n_models as u64, || {
        let r = loader.reload().expect("rescan");
        black_box(r.unchanged);
    });
    report("fleet/rescan_unchanged_per_model", &m);
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        if let Some(line) = s.lines().find(|l| l.starts_with("VmRSS")) {
            println!("steady-state with {n_models} resident models: {}", line.trim());
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
