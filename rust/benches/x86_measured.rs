//! Real x86 measurements — the anchored point of the Fig 3 reproduction
//! plus the layout ablation.
//!
//! Two measurement paths on this host (an actual x86-64 machine, like
//! the paper's EPYC column):
//!
//! 1. the rust inference engines (reference semantics of the generated
//!    C; compiled by rustc -O),
//! 2. the *actual generated C* compiled with gcc -O3 (the paper's exact
//!    methodology, §IV: "-O3 compiler flag", 10,000 replications) — in
//!    both if-else and native layouts.

use intreeger::codegen::{self, CBinary, Layout};
use intreeger::data::{esa_like, shuttle_like, Dataset};
use intreeger::inference::{Engine, FlIntEngine, FloatEngine, IntEngine, Variant};
use intreeger::ir::Model;
use intreeger::trees::{ForestParams, RandomForest};
use intreeger::util::bench::{black_box, measure, report, section};

fn rust_engines(name: &str, ds: &Dataset, model: &Model) {
    section(&format!("rust engines — {name}"));
    let rows: Vec<&[f32]> = (0..ds.n_rows().min(2000)).map(|i| ds.row(i)).collect();
    let fe = FloatEngine::compile(model);
    let fl = FlIntEngine::compile(model);
    let ie = IntEngine::compile(model);

    let m_f = measure(2, 7, rows.len() as u64, || {
        let mut acc = 0u32;
        for r in &rows {
            acc ^= fe.predict(r);
        }
        black_box(acc);
    });
    report(&format!("{name}/float"), &m_f);
    let m_fl = measure(2, 7, rows.len() as u64, || {
        let mut acc = 0u32;
        for r in &rows {
            acc ^= fl.predict(r);
        }
        black_box(acc);
    });
    report(&format!("{name}/flint"), &m_fl);
    let m_i = measure(2, 7, rows.len() as u64, || {
        let mut acc = 0u32;
        for r in &rows {
            acc ^= ie.predict(r);
        }
        black_box(acc);
    });
    report(&format!("{name}/intreeger"), &m_i);
    println!(
        "speedup float->intreeger: {:.2}x   float->flint: {:.2}x",
        m_f.per_item_ns() / m_i.per_item_ns(),
        m_f.per_item_ns() / m_fl.per_item_ns()
    );
}

fn generated_c(name: &str, ds: &Dataset, model: &Model) {
    if !codegen::compile::gcc_available() {
        println!("(gcc unavailable — skipping generated-C measurements)");
        return;
    }
    section(&format!("generated C via gcc -O3 — {name}"));
    let n_rows = ds.n_rows().min(2000);
    let rows: Vec<f32> = ds.features[..n_rows * ds.n_features].to_vec();
    let reps = 40;

    let mut results: Vec<(String, f64)> = Vec::new();
    for layout in [Layout::IfElse, Layout::Native] {
        for variant in Variant::all() {
            let src = codegen::generate(model, layout, variant);
            let bin = CBinary::compile(&src, variant, ds.n_features, ds.n_classes, "bench")
                .expect("gcc compile");
            let ns = bin.bench_ns(&rows, reps).expect("bench run");
            println!(
                "bench {name}/c/{}/{:<10} {:>12.1} ns/inference   (text {} B)",
                layout.name(),
                variant.name(),
                ns,
                bin.text_size.map(|s| s.to_string()).unwrap_or_else(|| "?".into())
            );
            results.push((format!("{}/{}", layout.name(), variant.name()), ns));
        }
    }
    let get = |k: &str| results.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap();
    println!(
        "if-else: float->intreeger {:.2}x; flint->intreeger {:.2}x; native/ifelse (int) {:.2}x",
        get("ifelse/float") / get("ifelse/intreeger"),
        get("ifelse/flint") / get("ifelse/intreeger"),
        get("native/intreeger") / get("ifelse/intreeger"),
    );
}

fn main() {
    println!("E5 (x86 column, measured) + layout ablation — gcc -O3, 10k-replication style");
    let shuttle = shuttle_like(12_000, 6);
    let esa = esa_like(4_000, 6);
    for (name, ds, trees) in [("shuttle/50t", &shuttle, 50usize), ("esa/20t", &esa, 20)] {
        let model = RandomForest::train(
            ds,
            &ForestParams { n_trees: trees, max_depth: 7, ..Default::default() },
            17,
        );
        rust_engines(name, ds, &model);
        generated_c(name, ds, &model);
    }
}
