//! E3/E5 — reproduces Table I and Fig 3: elapsed cycles per inference
//! for {float, FlInt, InTreeger} × {shuttle, esa} × n_trees × the three
//! application cores, via the trace-driven architecture simulator.
//!
//! Paper shape targets: InTreeger fastest everywhere; gains grow with
//! the dataset's class count (Shuttle 7 classes ≫ ESA 2 classes);
//! best case ≈2.1× on Shuttle/ARMv7/50 trees; ESA/ARMv7 averages only a
//! few percent; x86/RISC-V in between.

use intreeger::data::{esa_like, shuttle_like, Dataset};
use intreeger::inference::Variant;
use intreeger::simarch::{self, Core};
use intreeger::trees::{ForestParams, RandomForest};

fn run(name: &str, ds: &Dataset, tree_counts: &[usize]) {
    println!("\n--- dataset: {name} ({} classes, {} features) ---", ds.n_classes, ds.n_features);
    println!(
        "{:>22} {:>6} {:>14} {:>14} {:>14} {:>9} {:>9}",
        "core", "trees", "float cyc", "flint cyc", "intreeger cyc", "spd f/i", "spd fl/i"
    );
    for core in Core::application_cores() {
        let mut speedups = Vec::new();
        for &n in tree_counts {
            let model = RandomForest::train(
                ds,
                &ForestParams { n_trees: n, max_depth: 7, ..Default::default() },
                7,
            );
            let f = simarch::simulate(&model, ds, Variant::Float, core, 250);
            let fl = simarch::simulate(&model, ds, Variant::FlInt, core, 250);
            let it = simarch::simulate(&model, ds, Variant::IntTreeger, core, 250);
            let s_fi = f.cycles / it.cycles;
            speedups.push(s_fi);
            println!(
                "{:>22} {:>6} {:>14.0} {:>14.0} {:>14.0} {:>8.2}x {:>8.2}x",
                core.name(),
                n,
                f.cycles,
                fl.cycles,
                it.cycles,
                s_fi,
                fl.cycles / it.cycles
            );
        }
        let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
        println!(
            "{:>22} {:>6} avg speedup float->intreeger: {:.2}x (runtime reduction {:.1}%)",
            core.name(),
            "-",
            avg,
            (1.0 - 1.0 / avg) * 100.0
        );
    }
}

fn main() {
    println!("Table I — evaluation cores (simulated; see DESIGN.md §Substitutions):\n");
    print!("{}", simarch::cores::table_i());

    println!("\nFig 3 — elapsed cycles per inference (trace-driven cost model)");
    let shuttle = shuttle_like(12_000, 2);
    let esa = esa_like(6_000, 2);
    let counts = [10usize, 20, 50, 100];
    run("shuttle-like", &shuttle, &counts);
    run("esa-like", &esa, &counts);

    println!("\npaper anchors: Shuttle/ARMv7/50 trees ≈ 2.1x; ESA/ARMv7 avg reduction ≈ 4.8%;");
    println!("the x86 column is additionally measured for real by `cargo bench --bench x86_measured`.");
}
