//! Offline stub of the XLA/PJRT bindings used by [`intreeger`]'s runtime
//! layer (`rust/src/runtime/pjrt.rs`).
//!
//! The build container has neither crates.io access nor a PJRT plugin, so
//! this crate mirrors the type surface of the real bindings just enough
//! for the runtime layer to typecheck. Every entry point fails fast:
//! [`PjRtClient::cpu`] returns [`Error::Unavailable`], which
//! `PjrtEngine::load` surfaces as "XLA engine unavailable" and the
//! coordinator answers with the scalar batched route instead. Swapping
//! this path dependency for the real `xla` crate re-enables the PJRT
//! route with no source changes in `intreeger`.

use std::fmt;
use std::path::Path;

/// Stub error: the runtime is not present in this build.
#[derive(Debug)]
pub enum Error {
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable => {
                write!(f, "XLA/PJRT runtime not available (offline stub build)")
            }
        }
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u32 {}
impl ArrayElement for u64 {}

/// A PJRT device handle (never constructed by the stub).
pub struct PjRtDevice;

/// A device buffer handle (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// A host-side literal value.
pub struct Literal;

impl Literal {
    /// Unwrap a 1-tuple result (lowered with `return_tuple=True`).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable bound to a client.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// The PJRT client. The stub's constructor always fails, so no other stub
/// method is reachable in practice (they still typecheck call sites).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn hlo_load_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
