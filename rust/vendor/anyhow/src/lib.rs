//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the API subset the intreeger crate uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` conversion
//! (powering `?` on any std error) coherent.

use std::fmt;

/// Type-erased error: a message, optionally captured from a source error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from a preformatted message (used by the `anyhow!` macro).
    pub fn from_msg(msg: String) -> Error {
        Error { msg }
    }

    /// Construct from any displayable value.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::from_msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    fn guarded(x: i32) -> Result<i32> {
        ensure!(x >= 0, "negative input {x}");
        if x > 100 {
            bail!("too big: {x}");
        }
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
        assert_eq!(guarded(5).unwrap(), 5);
        assert_eq!(guarded(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(guarded(101).unwrap_err().to_string(), "too big: 101");
    }
}
